"""mixtral-8x22b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,          # GQA kv=8
    d_ff=16384,
    vocab=32768,
    window=4096,           # sliding-window attention → long_500k is runnable
    rope_theta=1e6,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    window=64, moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=256),
)
