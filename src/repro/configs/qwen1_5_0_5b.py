"""qwen1.5-0.5b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,          # GQA kv=16 (full MHA)
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
    skip_reasons={"long_500k": "pure full attention"},
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
)
