"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256),
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=128, vocab=512,
    ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, chunk=32),
)
