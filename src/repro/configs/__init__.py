"""Config registry: ``--arch <id>`` resolution for all assigned architectures."""
from __future__ import annotations

from typing import Dict

from .base import (  # noqa: F401
    EncDecConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    TrainConfig,
    VisionConfig,
)
from . import (
    chatglm3_6b,
    gemma3_12b,
    mamba2_370m,
    mixtral_8x22b,
    phi_3_vision_4_2b,
    qwen1_5_0_5b,
    qwen2_7b,
    qwen3_moe_30b_a3b,
    whisper_tiny,
    zamba2_2_7b,
)

_MODULES = (
    mixtral_8x22b,
    qwen3_moe_30b_a3b,
    zamba2_2_7b,
    mamba2_370m,
    phi_3_vision_4_2b,
    gemma3_12b,
    qwen1_5_0_5b,
    chatglm3_6b,
    qwen2_7b,
    whisper_tiny,
)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKE_ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.SMOKE for m in _MODULES}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE_ARCHS if smoke else ARCHS
    try:
        return table[arch]
    except KeyError:
        raise ValueError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}") from None


def cells(include_skipped: bool = False):
    """All (arch × shape) dry-run cells; skipped ones carry their reason."""
    out = []
    for arch, cfg in ARCHS.items():
        for shape_name, shape in SHAPES.items():
            skipped = shape_name in cfg.skip_shapes
            if skipped and not include_skipped:
                continue
            out.append((arch, shape_name,
                        cfg.skip_reasons.get(shape_name) if skipped else None))
    return out
