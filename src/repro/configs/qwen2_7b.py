"""qwen2-7b [dense] — GQA kv=4, QKV bias. [arXiv:2407.10671; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,           # GQA kv=4
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    skip_shapes=("long_500k",),
    skip_reasons={"long_500k": "pure full attention"},
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
)
