"""Sharded checkpointing: async writes, integrity manifest, cross-mesh restore.

Layout (one directory per step):
    step_000420/
      manifest.json      tree structure, shapes, dtypes, step, config hash
      <leafkey>.npy      one file per pytree leaf

On a real multi-host fleet each host writes only the shards it owns; here a
single process owns everything, but the manifest format and the restore path
(load → ``jax.device_put`` with *target* shardings) already support restoring
onto a different mesh shape — that is the elastic-scaling path: checkpoint on
N slices, resume on M.

Writes go through ``AsyncCheckpointer``: the step thread snapshots device
arrays to host memory synchronously (cheap) and a background thread does the
file I/O, so training never blocks on disk. A ``.complete`` marker commits a
checkpoint; restore ignores uncommitted directories (crash during write is
harmless).
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.numpy import bfloat16 as _BF16


def _leaf_key(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "__".join(out) or "root"


def save_checkpoint(directory: str, step: int, state: Any,
                    meta: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous save. Returns the committed checkpoint path."""
    host_state = jax.tree.map(lambda x: np.asarray(x), state)
    return _write(directory, step, host_state, meta or {})


def _write(directory: str, step: int, host_state: Any,
           meta: Dict[str, Any]) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(host_state)[0]
    manifest: Dict[str, Any] = {"step": step, "meta": meta, "leaves": {}}
    for p, leaf in leaves:
        key = _leaf_key(p)
        arr = np.asarray(leaf)
        logical_dtype = str(arr.dtype)
        if arr.dtype == _BF16:          # np.save can't serialise ml_dtypes
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, key + ".npy"), arr)
        manifest["leaves"][key] = {
            "shape": list(arr.shape),
            "dtype": logical_dtype,
            "bytes": int(arr.nbytes),
            "crc": _crc(arr),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    open(os.path.join(path, ".complete"), "w").close()
    return path


def _crc(arr: np.ndarray) -> str:
    return hashlib.md5(np.ascontiguousarray(arr).tobytes()[:1 << 20]).hexdigest()


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, ".complete")):
            steps.append((int(m.group(1)), d))
    if not steps:
        return None
    return os.path.join(directory, max(steps)[1])


def restore_checkpoint(path: str, like: Any,
                       shardings: Optional[Any] = None,
                       verify: bool = True) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings`` (same structure) places leaves onto the
    *current* mesh — which may differ from the saving mesh (elastic restore).
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    paths_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    sh_flat = (jax.tree.flatten(shardings)[0]
               if shardings is not None else [None] * len(paths_like))
    out: List[Any] = []
    for (p, leaf), sh in zip(paths_like, sh_flat):
        key = _leaf_key(p)
        if key not in leaves_meta:
            raise KeyError(f"checkpoint {path} missing leaf {key}")
        arr = np.load(os.path.join(path, key + ".npy"))
        if verify and _crc(arr) != leaves_meta[key]["crc"]:
            raise IOError(f"checksum mismatch for {key} in {path}")
        if leaves_meta[key]["dtype"] == "bfloat16":
            arr = arr.view(_BF16)
        want_shape = tuple(leaf.shape)
        if arr.shape != want_shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != wanted {want_shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest


def prune_checkpoints(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    done = sorted(d for d in os.listdir(directory)
                  if re.fullmatch(r"step_\d+", d)
                  and os.path.exists(os.path.join(directory, d, ".complete")))
    for d in done[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d))


class AsyncCheckpointer:
    """Background-thread writer: snapshot on the caller, I/O off-thread."""

    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue[Optional[Tuple[int, Any, Dict]]]" = queue.Queue()
        self._errors: List[BaseException] = []
        self._written: List[str] = []
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def save(self, step: int, state: Any,
             meta: Optional[Dict[str, Any]] = None) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(x), state)  # sync copy
        self._q.put((step, host_state, meta or {}))

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_state, meta = item
            try:
                self._written.append(
                    _write(self.directory, step, host_state, meta))
                prune_checkpoints(self.directory, self.keep)
            except BaseException as e:  # noqa: BLE001
                self._errors.append(e)

    def wait(self) -> List[str]:
        self._q.put(None)
        self._thread.join()
        if self._errors:
            raise self._errors[0]
        return list(self._written)
