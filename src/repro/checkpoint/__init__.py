from .ckpt import (  # noqa: F401
    AsyncCheckpointer,
    latest_checkpoint,
    prune_checkpoints,
    restore_checkpoint,
    save_checkpoint,
)
