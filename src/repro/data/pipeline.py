"""Deterministic synthetic token pipeline with sequence packing.

Real enough to train against: documents with Zipf-distributed token ids and
lognormal lengths are packed into fixed-length rows (greedy bin fill with
separator tokens), and every (host_shard, step) batch is a pure function of
the seed — so restarts resume bit-identically mid-epoch (checkpoint stores
only ``step``), and each data-parallel host generates exactly its shard.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    doc_len_median: float = 350.0
    doc_len_sigma: float = 1.0
    bos: int = 1
    shards: int = 1                 # data-parallel host count
    shard_id: int = 0


class TokenPipeline:
    """Stateless batch source: ``batch(step)`` is deterministic."""

    def __init__(self, cfg: DataConfig) -> None:
        assert cfg.global_batch % cfg.shards == 0
        self.cfg = cfg
        self.per_shard = cfg.global_batch // cfg.shards

    def _rng(self, step: int, row: int) -> np.random.Generator:
        c = self.cfg
        return np.random.default_rng(
            (c.seed * 1_000_003 + step) * 65_537 + c.shard_id * 131 + row)

    def _pack_row(self, rng: np.random.Generator) -> np.ndarray:
        c = self.cfg
        row = np.empty(c.seq_len + 1, np.int32)
        fill = 0
        while fill < c.seq_len + 1:
            n = int(rng.lognormal(np.log(c.doc_len_median), c.doc_len_sigma))
            n = max(8, min(n, c.seq_len))
            doc = rng.zipf(c.zipf_a, size=n).astype(np.int64)
            doc = (doc % (c.vocab - 2)) + 2          # reserve 0=pad, 1=bos
            take = min(n + 1, c.seq_len + 1 - fill)
            row[fill] = c.bos
            row[fill + 1: fill + take] = doc[: take - 1]
            fill += take
        return row

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rows = np.stack([self._pack_row(self._rng(step, r))
                         for r in range(self.per_shard)])
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def synthetic_extras(family: str, batch: int, cfg,
                     rng: Optional[np.random.Generator] = None
                     ) -> Dict[str, np.ndarray]:
    """Stub-frontend inputs (vlm patches / audio frames) for smoke runs."""
    rng = rng or np.random.default_rng(0)
    if family == "vlm":
        v = cfg.vision
        return {"patches": rng.normal(
            0, 1, (batch, v.n_patches, v.patch_dim)).astype(np.float32)}
    if family == "audio":
        e = cfg.encdec
        return {"frames": rng.normal(
            0, 0.1, (batch, e.n_frames, cfg.d_model)).astype(np.float32)}
    return {}
