from .pipeline import DataConfig, TokenPipeline, synthetic_extras  # noqa: F401
