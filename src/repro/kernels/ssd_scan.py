"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid = (B·H, S/Q): the chunk axis is innermost and sequential on TPU, so the
inter-chunk SSM state h (P×N, f32) lives in VMEM scratch and flows across
grid steps — the recurrence costs no HBM round-trips. Within a chunk the
dual (attention-like) form runs on the MXU:

    L   = exp(segsum(dA))            (Q×Q lower-triangular decay)
    y   = (C·Bᵀ ∘ L) · (dt·x)        intra-chunk
        + (C · h_in) ∘ exp(cumsum dA) inter-chunk
    h' += decay-weighted chunk state

Q (chunk) and P (head dim) are the MXU tile knobs; N (SSM state) rides the
lane dimension. Group-to-head mapping (GVA) happens in the B/C index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1) — padded lane dim
    a = a_ref[0].astype(jnp.float32)          # (1, 1)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    dA = dt * a                               # (Q, 1), ≤ 0
    cum = jnp.cumsum(dA, axis=0)              # (Q, 1) inclusive
    # segsum(i, j) = cum[i] - cum[j]  for i ≥ j (strictly: sum_{j+1..i})
    seg = cum - cum.reshape(1, chunk)         # (Q, Q) via broadcast
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)

    xdt = x * dt                              # (Q, P)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q, Q)
    y = jax.lax.dot_general(cb * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q, P)

    # inter-chunk: contribution of the incoming state
    h_in = h_ref[...]                         # (P, N)
    decay_in = jnp.exp(cum)                   # (Q, 1)
    y += decay_in * jax.lax.dot_general(
        c, h_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (Q, N)·(P, N)ᵀ → (Q, P)

    # state update: h' = h·exp(sum dA) + Σ_s exp(cum[-1]-cum[s]) dt_s x_s B_sᵀ
    total = cum[chunk - 1]                    # (1,)
    w = jnp.exp(total.reshape(1, 1) - cum)    # (Q, 1)
    hs = jax.lax.dot_general(xdt * w, b, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (P, N)
    h_ref[...] = h_in * jnp.exp(total).reshape(1, 1) + hs
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(xh: jax.Array, dt: jax.Array, a: jax.Array,
                    B_: jax.Array, C_: jax.Array, *, chunk: int = 256,
                    interpret: bool = False):
    """xh: (B, S, H, P); dt: (B, S, H); a: (H,); B_/C_: (B, S, G, N).

    Returns (y: (B, S, H, P), h_final is not emitted — training path only).
    """
    Bb, S, H, P = xh.shape
    G, N = B_.shape[2], B_.shape[3]
    R = H // G
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    xr = xh.transpose(0, 2, 1, 3).reshape(Bb * H, S, P)
    dtr = dt.transpose(0, 2, 1).reshape(Bb * H, S, 1)
    ar = a.reshape(H, 1, 1)
    br = B_.transpose(0, 2, 1, 3).reshape(Bb * G, S, N)
    cr = C_.transpose(0, 2, 1, 3).reshape(Bb * G, S, N)

    def bc_index(bh, ic):
        b, h = bh // H, bh % H
        return (b * G + h // R, ic, 0)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=Q),
        grid=(Bb * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, Q, 1), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((1, 1, 1), lambda bh, ic: (bh % H, 0, 0)),
            pl.BlockSpec((1, Q, N), bc_index),
            pl.BlockSpec((1, Q, N), bc_index),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb * H, S, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, br, cr)
    return y.reshape(Bb, H, S, P).transpose(0, 2, 1, 3), None
