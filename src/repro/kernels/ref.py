"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are *the* semantics; kernels must match them on all shape/dtype sweeps
(tests/test_kernels.py). They deliberately share no code with the kernels.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, S, Hq, D); k/v: (B, T, Hkv, D) → (B, S, Hq, D)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qh = q.reshape(B, S, Hkv, g, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qh, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool) if not causal else (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, Hq, D)


def moe_gmm_ref(buf: jax.Array, w: jax.Array) -> jax.Array:
    """Grouped GEMM: (E, C, d) × (E, d, f) → (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", buf, w)


def ssd_scan_ref(xh: jax.Array, dt: jax.Array, a: jax.Array,
                 B_: jax.Array, C_: jax.Array,
                 h0: Optional[jax.Array] = None,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    xh: (B, S, H, P); dt: (B, S, H); a: (H,) ≤ 0; B_/C_: (B, S, G, N).
    h_t = h_{t-1}·exp(dt_t·a) + dt_t·x_t⊗B_t;  y_t = C_t·h_t.
    Returns (y: (B, S, H, P), h_final: (B, H, P, N)).
    """
    Bb, S, H, P = xh.shape
    G, N = B_.shape[2], B_.shape[3]
    R = H // G
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                       # (B,H,P),(B,H),(B,G,N)
        dA = jnp.exp(dt_t * a[None, :])                 # (B,H)
        bh = jnp.repeat(b_t.astype(jnp.float32), R, axis=1)  # groups→heads
        ch = jnp.repeat(c_t.astype(jnp.float32), R, axis=1)
        xb = jnp.einsum("bhp,bhn->bhpn",
                        (x_t * dt_t[..., None]).astype(jnp.float32), bh)
        h = h * dA[..., None, None] + xb
        y = jnp.einsum("bhn,bhpn->bhp", ch, h)
        return h, y

    xs = (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B_.transpose(1, 0, 2, 3), C_.transpose(1, 0, 2, 3))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(xh.dtype), h_final
