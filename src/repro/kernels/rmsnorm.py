"""Fused RMSNorm Pallas kernel.

Memory-bound fusion: one pass over the rows — read x, compute the f32
mean-square in VREGs, scale, write — instead of XLA's separate
square/reduce/rsqrt/mul chain. Rows are tiled (block_rows, d) into VMEM;
``d`` stays whole per block (norm axis must be resident).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (bm, d)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = False
                   ) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    bm = min(block_rows, rows)
    while rows % bm:
        bm //= 2
    bm = max(bm, 1)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
