"""Flash attention (forward) Pallas TPU kernel: causal / sliding-window / GQA.

TPU adaptation of the flash algorithm (DESIGN.md §6): q/k/v blocks are tiled
into VMEM with MXU-aligned shapes (block_q × head_dim and block_k × head_dim,
multiples of 128 where the head dim allows); the online-softmax statistics
(m, l) and the f32 accumulator live in VMEM scratch and persist across the
innermost (kv) grid dimension, which TPU executes sequentially. Sliding
windows skip nothing structurally (grid is static) but fully-masked kv
blocks short-circuit via ``pl.when`` so they cost neither DMA waits nor MXU
issue slots on real hardware.

GQA is expressed in the BlockSpec index maps: the kv block index maps
q-head → kv-head (h // group), so no repeated K/V materialisation.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                  acc_ref, *, scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, kv_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = iq * block_q
    k_lo = ik * block_k
    # block-level reachability (static grid; dynamic skip)
    reachable = True
    if causal:
        reachable = k_lo <= q_lo + block_q - 1
    in_window = True
    if window > 0:
        in_window = k_lo + block_k - 1 > q_lo - window

    @pl.when(jnp.asarray(reachable) & jnp.asarray(in_window))
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                # fully-masked rows
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        # logsumexp rows — consumed by the backward kernels
        lse_ref[0] = (m_ref[...] + jnp.log(l))[:, 0]


def _blocks(S: int, T: int, block_q: int, block_k: int):
    bq = min(block_q, S)
    bk = min(block_k, T)
    while S % bq:
        bq //= 2
    while T % bk:
        bk //= 2
    return bq, bk


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """→ (out (B,S,Hq,D), lse (B*Hq, S))."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    bq, bk = _blocks(S, T, block_q, block_k)
    scale = 1.0 / math.sqrt(D)

    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)

    def kv_index(h, iq, ik):
        b, hq = h // Hq, h % Hq
        return (b * Hkv + hq // group, ik, 0)

    out, lse = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=bq, block_k=bk, kv_len=T),
        grid=(B * Hq, S // bq, T // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bq), lambda h, iq, ik: (h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
            jax.ShapeDtypeStruct((B * Hq, S), jnp.float32),
        ],
        scratch_shapes=[
            # (bq, 1) running max / sum, (bq, D) f32 accumulator — VMEM
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, S, D).transpose(0, 2, 1, 3), lse


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, S, Hq, D); k/v: (B, T, Hkv, D) → (B, S, Hq, D)."""
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)[0]


# ---------------------------------------------------------------------------
# backward (flash v2 style): one kernel for dq (kv innermost), one for dk/dv
# (q innermost). ds = p ∘ (do·vᵀ − Δ) with Δ = rowsum(do ∘ o); p recomputed
# from the saved logsumexp — no S×T materialisation anywhere.
# ---------------------------------------------------------------------------
def _mask(s_shape, q_lo, k_lo, causal, window, kv_len):
    q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s_shape, 0)
    k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s_shape, 1)
    m = k_pos < kv_len
    if causal:
        m &= k_pos <= q_pos
    if window > 0:
        m &= k_pos > q_pos - window
    return m


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, acc_ref, *, scale, causal, window,
                         block_q, block_k, kv_len):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = pl.program_id(1) * block_q
    k_lo = ik * block_k
    reachable = (k_lo <= q_lo + block_q - 1) if causal else True
    in_window = (k_lo + block_k - 1 > q_lo - window) if window > 0 else True

    @pl.when(jnp.asarray(reachable) & jnp.asarray(in_window))
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask(s.shape, q_lo, k_lo, causal, window, kv_len)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0][:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None])
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal,
                          window, block_q, block_k, kv_len, nq_per_head):
    jq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(jq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    k_lo = pl.program_id(1) * block_k
    # jq walks (group × q-blocks); the q row block is jq % nq_per_head
    q_lo = (jq % nq_per_head) * block_q

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _mask(s.shape, q_lo, k_lo, causal, window, kv_len)
    p = jnp.where(mask, jnp.exp(s - lse_ref[0][:, None]), 0.0)
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0][:, None])
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32) * scale

    @pl.when(jq == nq - 1)
    def _done():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Returns (dq, dk, dv). lse: (B*Hq, S) from the forward."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    bq, bk = _blocks(S, T, block_q, block_k)
    scale = 1.0 / math.sqrt(D)
    nq = S // bq

    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, D)
    dor = do.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    # Δ = rowsum(do ∘ o) — cheap elementwise precompute
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1).transpose(0, 2, 1).reshape(B * Hq, S)

    def kv_index(h, iq, ik):
        b, hq = h // Hq, h % Hq
        return (b * Hkv + hq // group, ik, 0)

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, block_q=bq, block_k=bk, kv_len=T),
        grid=(B * Hq, nq, T // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bq), lambda h, iq, ik: (h, iq)),
            pl.BlockSpec((1, bq), lambda h, iq, ik: (h, iq)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, dor, lse, delta)

    # dk/dv: grid walks (b·kv-head, k-block, group·q-blocks); the q-side
    # index map routes each (group, q-block) pair to the right q head
    def q_index(hk, ik, j):
        b, hkv = hk // Hkv, hk % Hkv
        g, iq = j // nq, j % nq
        return (b * Hq + hkv * group + g, iq, 0)

    def q_row_index(hk, ik, j):
        b, hkv = hk // Hkv, hk % Hkv
        g, iq = j // nq, j % nq
        return (b * Hq + hkv * group + g, iq)

    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, block_q=bq, block_k=bk, kv_len=T,
                          nq_per_head=nq),
        grid=(B * Hkv, T // bk, group * nq),
        in_specs=[
            pl.BlockSpec((1, bq, D), q_index),
            pl.BlockSpec((1, bk, D), lambda hk, ik, j: (hk, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda hk, ik, j: (hk, ik, 0)),
            pl.BlockSpec((1, bq, D), q_index),
            pl.BlockSpec((1, bq), q_row_index),
            pl.BlockSpec((1, bq), q_row_index),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda hk, ik, j: (hk, ik, 0)),
            pl.BlockSpec((1, bk, D), lambda hk, ik, j: (hk, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, T, D), k.dtype),
            jax.ShapeDtypeStruct((B * Hkv, T, D), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, dor, lse, delta)

    rs = lambda t, H: t.reshape(B, H, -1, D).transpose(0, 2, 1, 3)  # noqa: E731
    return rs(dq, Hq), rs(dk, Hkv), rs(dv, Hkv)
