# Pallas TPU kernels for the substrate's compute hot-spots:
#   flash_attention  — causal/SWA/GQA fused attention (VMEM-tiled, online
#                      softmax)
#   moe_gmm          — grouped expert GEMM (capacity-bucketed, MXU tiles)
#   ssd_scan         — Mamba2 SSD chunked scan (state carried in VMEM)
#   rmsnorm          — fused single-pass norm
# Each has a pure-jnp oracle in ref.py; ops.py exposes jit'd wrappers that
# interpret on CPU and compile natively on TPU.
from . import ops, ref  # noqa: F401
