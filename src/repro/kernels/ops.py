"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — Python
evaluation of the kernel body, used by the test suite to validate against
the ``ref.py`` oracles. On TPU backends they compile natively. The model
code calls these through ``use_pallas=True``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import (
    flash_attention_bwd,
    flash_attention_fwd,
    flash_attention_pallas,
)
from .moe_gmm import moe_gmm_pallas
from .rmsnorm import rmsnorm_pallas
from .ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# differentiable flash attention: Pallas forward + Pallas flash-v2 backward
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash(q, k, v, causal: bool, window: int):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=_interpret())[0]


def _flash_fwd(q, k, v, causal, window):
    o, lse = flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 interpret=_interpret())
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, res, do):
    q, k, v, o, lse = res
    return flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                               window=window, interpret=_interpret())


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    return _flash(q, k, v, causal, window)


@jax.jit
def rmsnorm(x, scale, eps: float = 1e-6):
    return rmsnorm_pallas(x, scale, eps, interpret=_interpret())


@jax.jit
def moe_gmm(buf, w):
    return moe_gmm_pallas(buf, w, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xh, dt, a, B_, C_, *, chunk: int = 256):
    return ssd_scan_pallas(xh, dt, a, B_, C_, chunk=chunk,
                           interpret=_interpret())
