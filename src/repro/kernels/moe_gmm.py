"""Grouped expert GEMM (MoE FFN) Pallas TPU kernel.

Computes out[e] = buf[e] @ w[e] for every expert with one kernel launch:
grid = (E, C/bc, F/bf, D/bd), MXU-aligned (128×128) tiles, f32 accumulator
in VMEM scratch across the contraction (innermost) grid dimension. This is
the TPU-native replacement for megablocks-style grouped GEMM — capacity
bucketing upstream makes every expert's tile count identical, so there is no
ragged indexing on the hot path (the sort/scatter bookkeeping stays in XLA
where it is memory-bound anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    kd = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm_pallas(buf: jax.Array, w: jax.Array, *, block_c: int = 128,
                   block_f: int = 128, block_d: int = 256,
                   interpret: bool = False) -> jax.Array:
    """buf: (E, C, D) tokens-per-expert; w: (E, D, F) → (E, C, F)."""
    E, C, D = buf.shape
    F = w.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    while C % bc:
        bc //= 2
    while F % bf:
        bf //= 2
    while D % bd:
        bd //= 2
    out = pl.pallas_call(
        _gmm_kernel,
        grid=(E, C // bc, F // bf, D // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), buf.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(buf, w)
    return out
