"""Real local executor: the CWS driving actual Python/JAX work.

This is the proof that the control plane is not simulation-only: the same
``CommonWorkflowScheduler`` + CWSI used by the simulator here launches real
callables (typically jitted step functions) on a thread pool, with wall-clock
time feeding the provenance store and the online predictors.

Each registered "node" is a worker lane with cpu/memory bookkeeping — on a
real deployment these lanes map to TPU slices; here they map to host threads
(the container has a single core, so lanes mostly pipeline I/O-free work).
"""
from __future__ import annotations

import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional

from ..core import commands as _cmd
from ..core.dag import Task, WorkflowDAG
from ..core.scheduler import CommonWorkflowScheduler, NodeInfo, TaskResult


class LocalExecutor:
    """Implements ClusterAdapter against a thread pool and wall-clock time."""

    def __init__(self, nodes: List[NodeInfo], max_workers: Optional[int] = None):
        self._nodes = list(nodes)
        self._pool = ThreadPoolExecutor(max_workers=max_workers or len(nodes) * 2)
        self._lock = threading.RLock()          # CWS engine is not thread-safe
        self._t0 = time.monotonic()
        self._cancelled: Dict[str, bool] = {}
        # task_id -> live launch id: lets a finishing worker retire its
        # own cancel-flag entry without clobbering a relaunch's (kills —
        # speculation losers and arbiter preemptions alike — may be
        # followed by a relaunch of the same task id)
        self._launches: Dict[str, int] = {}
        self.cws: Optional[CommonWorkflowScheduler] = None
        self.outputs: Dict[str, Any] = {}

    def now(self) -> float:
        return time.monotonic() - self._t0

    def attach(self, cws: CommonWorkflowScheduler) -> None:
        self.cws = cws
        with self._lock:
            # commands through the apply seam, same as the simulator: a
            # journaled engine records this executor's history verbatim
            for n in self._nodes:
                cws.apply(_cmd.AddNode(n), self.now())

    # ---- ClusterAdapter ----
    def launch(self, task: Task, node: str, mem_alloc: int) -> None:
        # a gang launch (task.gang_nodes spans k lanes) still runs as ONE
        # worker, seated at the head lane: the engine holds the resource
        # reservations on every member, and a jitted multi-device step
        # drives all devices from a single host thread anyway
        self._cancelled[task.task_id] = False
        self._launches[task.task_id] = task.launch_id
        # capture the launch id now: the Task object is shared, so a
        # relaunch would otherwise make a stale worker report under the
        # live launch's id
        self._pool.submit(self._run, task, node, task.launch_id)

    def kill(self, task_id: str) -> None:
        # cooperative: the worker's result is discarded. A preempted
        # task may be relaunched immediately after this kill; launch()
        # then resets the flag, and the *old* worker's late report is
        # rejected by the engine on its stale launch id. A kill with no
        # tracked launch (its worker already drained) has nobody left to
        # suppress — setting the flag would leak an entry forever.
        if task_id in self._launches:
            self._cancelled[task_id] = True

    def _run(self, task: Task, node: str, launch_id: int) -> None:
        assert self.cws is not None
        with self._lock:
            self.cws.apply(_cmd.TaskStarted(task.task_id,
                                            launch_id=launch_id),
                           self.now())
        t0 = time.monotonic()
        try:
            fn = task.spec.fn
            out = fn(**task.spec.params.get("kwargs", {})) if fn else None
            ok, reason = True, ""
        except Exception as e:  # noqa: BLE001 — task failure is data here
            out, ok, reason = None, False, f"{type(e).__name__}: {e}"
            traceback.print_exc()
        cpu_s = time.monotonic() - t0
        peak = 0
        if isinstance(out, dict) and "peak_mem_bytes" in out:
            peak = int(out["peak_mem_bytes"])
        with self._lock:
            cancelled = self._cancelled.get(task.task_id)
            if self._launches.get(task.task_id) == launch_id:
                # this worker owns the live launch: retire the cancel
                # bookkeeping — cancelled or not — so the maps stay
                # bounded by in-flight work (a killed-but-never-
                # relaunched task must not leak its entries)
                self._launches.pop(task.task_id, None)
                self._cancelled.pop(task.task_id, None)
            if cancelled:
                return
            if ok:
                self.outputs[task.task_id] = out
            self.cws.apply(
                _cmd.TaskFinished(
                    task.task_id,
                    TaskResult(ok, peak_mem_bytes=peak, cpu_seconds=cpu_s,
                               reason=reason, output=out),
                    launch_id=launch_id),
                self.now())
            # wall-clock completions have no same-instant batch to
            # coalesce with: run the deferred round now rather than
            # waiting up to poll_s for the driver loop to wake
            self.cws.schedule_pending(self.now())

    # ---- driver ----
    def run_to_completion(self, dag: WorkflowDAG, poll_s: float = 0.01,
                          timeout_s: float = 600.0) -> Dict[str, Any]:
        assert self.cws is not None
        with self._lock:
            self.cws.apply(_cmd.SubmitWorkflow(dag), self.now())
        deadline = time.monotonic() + timeout_s
        while True:
            with self._lock:
                if dag.finished():
                    break
                self.cws.apply(_cmd.ScheduleBarrier(force=True), self.now())
            if time.monotonic() > deadline:
                raise TimeoutError(f"workflow {dag.workflow_id} timed out")
            time.sleep(poll_s)
        return dict(self.outputs)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
