# Resource-manager substrate: node/slice profiles, the discrete-event
# cluster simulator (paper-methodology evaluation), nf-core-shaped traces,
# and a real thread-pool executor driven by the same CWS engine.
from .executor import LocalExecutor  # noqa: F401
from .faults import (  # noqa: F401
    DomainOutage,
    FaultInjector,
    FaultPlan,
    FaultyTransport,
    LaunchVerdict,
    NodeFlap,
)
from .nodes import (  # noqa: F401
    GiB,
    TPU_V5E,
    cpu_node,
    domain_cluster,
    heterogeneous_cluster,
    tpu_fleet,
    tpu_slice,
    uniform_cluster,
)
from .simulator import (  # noqa: F401
    ClusterSimulator,
    SimConfig,
    run_workflow,
    run_workflows,
)
from .traces import (  # noqa: F401
    Arrival,
    NF_CORE_TEMPLATES,
    NF_CORE_WORKFLOWS,
    TraceReplayer,
    build_workflow,
    burst_arrivals,
    poisson_arrivals,
    recorded_arrivals,
    template_task_count,
    trace_task_count,
    workflow_summary,
)
