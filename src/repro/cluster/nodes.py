"""Node/slice profiles for the cluster substrate.

Two kinds of resources appear in the framework:
  * CPU cluster nodes — the paper's own evaluation environment (commodity
    Kubernetes nodes running containerised workflow tasks);
  * TPU slices — the TPU adaptation: a "node" registered with the CWS is a
    gang-schedulable slice (sub-pod or pod) with chips + HBM, living inside
    an ICI domain; cross-slice traffic rides DCN.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

from ..core.scheduler import NodeInfo

GiB = 1 << 30

# TPU v5e hardware constants (single source of truth; §Roofline uses these).
TPU_V5E = {
    "peak_bf16_flops": 197e12,     # FLOP/s per chip
    "hbm_bandwidth": 819e9,        # bytes/s per chip
    "hbm_bytes": 16 * GiB,         # per chip
    "ici_bandwidth": 50e9,         # bytes/s per link (~50 GB/s/link)
    "dcn_bandwidth": 25e9,         # bytes/s per host across pods
}


def cpu_node(name: str, cpus: float = 8.0, mem_gib: int = 32,
             speed_factor: float = 1.0,
             labels: Optional[Dict[str, str]] = None) -> NodeInfo:
    return NodeInfo(name=name, cpus=cpus, mem_bytes=mem_gib * GiB,
                    chips=0, speed_factor=speed_factor, labels=labels or {})


def tpu_slice(name: str, chips: int = 256, speed_factor: float = 1.0,
              generation: str = "v5e",
              labels: Optional[Dict[str, str]] = None) -> NodeInfo:
    lab = {"accelerator": f"tpu-{generation}", **(labels or {})}
    return NodeInfo(
        name=name,
        cpus=chips / 4,                      # host cores per chip group
        mem_bytes=chips * TPU_V5E["hbm_bytes"],
        chips=chips,
        hbm_bytes_per_chip=int(TPU_V5E["hbm_bytes"]),
        speed_factor=speed_factor,
        labels=lab,
    )


def uniform_cluster(n_nodes: int, cpus: float = 4.0, mem_gib: int = 32,
                    prefix: str = "s") -> List[NodeInfo]:
    """A homogeneous N-node cluster (zero-padded names so the round-robin
    ring's name sort equals the registration order). Used by the
    node-scale placement sweep and the index oracle tests, where N runs
    to thousands."""
    width = max(len(str(max(n_nodes - 1, 0))), 2)
    return [cpu_node(f"{prefix}{i:0{width}d}", cpus, mem_gib)
            for i in range(n_nodes)]


def domain_cluster(n_domains: int, nodes_per_domain: int,
                   cpus: float = 4.0, mem_gib: int = 32,
                   key: str = "rack", prefix: str = "d") -> List[NodeInfo]:
    """A homogeneous cluster partitioned into failure domains.

    Node ``{prefix}{d}n{i}`` carries label ``{key: "{prefix}{d}"}``, so a
    ``faults.DomainOutage`` on domain ``"{prefix}{d}"`` takes out all of
    its ``nodes_per_domain`` members at one instant (the correlated-
    failure case a per-node fault schedule cannot express)."""
    return [
        cpu_node(f"{prefix}{d}n{i:02d}", cpus, mem_gib,
                 labels={key: f"{prefix}{d}"})
        for d in range(n_domains) for i in range(nodes_per_domain)
    ]


def heterogeneous_cluster(n_nodes: int = 6, cpus: float = 8.0,
                          mem_gib: int = 32,
                          speed_spread: float = 0.3) -> List[NodeInfo]:
    """A commodity cluster in the style of the paper's evaluation setup:
    ``n_nodes`` nodes whose speeds span ``1 ± speed_spread`` (deterministic
    spacing so experiments are reproducible)."""
    nodes = []
    for i in range(n_nodes):
        frac = i / max(n_nodes - 1, 1)
        speed = (1.0 - speed_spread) + 2 * speed_spread * frac
        nodes.append(cpu_node(f"node-{i:02d}", cpus, mem_gib, round(speed, 3)))
    return nodes


def tpu_fleet(n_pods: int = 2, chips_per_pod: int = 256,
              generations: Optional[List[str]] = None) -> List[NodeInfo]:
    """A fleet of pod-level slices; heterogeneous generations get speed
    factors proportional to their peak FLOP/s (v5p ≈ 2.3x v5e bf16)."""
    gen_speed = {"v5e": 1.0, "v5p": 2.33, "v4": 1.40}
    gens = generations or ["v5e"] * n_pods
    return [
        tpu_slice(f"pod-{i:02d}", chips_per_pod, gen_speed.get(g, 1.0), g)
        for i, g in enumerate(gens)
    ]
