"""nf-core-shaped workflow trace generation (Fig. 2 reproduction).

The paper evaluates the CWS on "the nine most popular nf-core workflows",
each run with its test profile on a commodity Kubernetes cluster. We model
each workflow as a staged DAG template (per-sample chains, chromosome
scatters, per-sample gathers, and workflow-wide merge points — the shapes
real nf-core pipelines have) and instantiate it with seeded sample sizes.

Ground truth (runtime at unit node speed, true peak memory) is drawn ONCE at
instantiation and stored in ``spec.base_runtime_s`` / ``spec.params['sim']``,
so that different scheduling strategies are compared on *identical* DAG
instances — only the schedule differs, as in the paper's experiment.

Runtime and memory scale affinely with input size (runtime ≈ a + b·GB), the
relationship the prediction literature (Lotaru, Witt) assumes and that the
CWSI exposes for learning.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Mapping,
                    Optional, Sequence, Tuple)

import numpy as np

from ..core import commands as _cmd
from ..core.dag import DataRef, Resources, TaskSpec, WorkflowDAG

GiB = 1 << 30


@dataclass(frozen=True)
class Stage:
    name: str
    kind: str                   # per_sample | scatter | gather | merge_all
    runtime_base_s: float       # runtime = base + per_gb * input_GB (×jitter)
    runtime_per_gb_s: float
    cpus: float = 2.0
    mem_req_gib: float = 8.0    # requested (usually over-provisioned)
    mem_base_gib: float = 1.0   # true peak = base + per_gb * input_GB
    mem_per_gb_gib: float = 0.2
    scatter: int = 1            # pieces per sample (kind == scatter)
    out_ratio: float = 0.8      # output bytes = ratio × input bytes
    jitter_sigma: float = 0.25  # per-task ground-truth lognormal spread


@dataclass(frozen=True)
class WorkflowTemplate:
    name: str
    stages: Tuple[Stage, ...]
    n_samples: int
    sample_gb_median: float
    sample_gb_sigma: float      # lognormal spread of sample sizes


def _s(name, kind, base, per_gb, **kw) -> Stage:
    return Stage(name=name, kind=kind, runtime_base_s=base,
                 runtime_per_gb_s=per_gb, **kw)


# ---------------------------------------------------------------------------
# The nine workflows of Fig. 2 (stage shapes follow the real pipelines;
# runtimes are scaled to test-profile magnitudes).
# ---------------------------------------------------------------------------
NF_CORE_TEMPLATES: Dict[str, WorkflowTemplate] = {
    "rnaseq": WorkflowTemplate("rnaseq", (
        _s("fastqc", "per_sample", 40, 12, cpus=2, mem_req_gib=6),
        _s("trimgalore", "per_sample", 60, 30, cpus=4, mem_req_gib=8),
        _s("star_align", "per_sample", 120, 90, cpus=6, mem_req_gib=20,
           mem_base_gib=16, mem_per_gb_gib=0.4),
        _s("samtools_sort", "per_sample", 30, 25, cpus=4, mem_req_gib=8),
        _s("markduplicates", "per_sample", 45, 35, cpus=3, mem_req_gib=12,
           mem_base_gib=4, mem_per_gb_gib=0.5),
        _s("salmon_quant", "per_sample", 60, 40, cpus=4, mem_req_gib=10),
        _s("qualimap", "per_sample", 35, 20, cpus=2, mem_req_gib=8),
        _s("multiqc", "merge_all", 90, 2, cpus=2, mem_req_gib=6),
    ), n_samples=10, sample_gb_median=4.0, sample_gb_sigma=0.5),

    "sarek": WorkflowTemplate("sarek", (
        _s("fastqc", "per_sample", 40, 10, cpus=2),
        _s("fastp", "per_sample", 50, 25, cpus=4),
        _s("bwa_mem", "per_sample", 150, 110, cpus=8, mem_req_gib=16,
           mem_base_gib=8, mem_per_gb_gib=0.3),
        _s("markduplicates", "per_sample", 60, 40, cpus=4, mem_req_gib=16,
           mem_base_gib=6, mem_per_gb_gib=0.4),
        _s("baserecalibrator", "scatter", 30, 18, cpus=2, scatter=6),
        _s("applybqsr", "scatter", 25, 15, cpus=2, scatter=6),
        _s("gatherbqsr", "gather", 20, 6, cpus=2),
        _s("haplotypecaller", "scatter", 70, 45, cpus=4, scatter=6,
           mem_req_gib=10),
        _s("mergevcfs", "gather", 25, 5, cpus=2),
        _s("snpeff", "per_sample", 50, 15, cpus=2, mem_req_gib=10),
        _s("multiqc", "merge_all", 80, 1.5, cpus=2),
    ), n_samples=6, sample_gb_median=8.0, sample_gb_sigma=0.6),

    "chipseq": WorkflowTemplate("chipseq", (
        _s("fastqc", "per_sample", 35, 12, cpus=2),
        _s("trimgalore", "per_sample", 55, 28, cpus=4),
        _s("bwa_mem", "per_sample", 110, 80, cpus=6, mem_req_gib=16,
           mem_base_gib=8, mem_per_gb_gib=0.3),
        _s("filter_bam", "per_sample", 40, 22, cpus=3),
        _s("macs2", "per_sample", 80, 35, cpus=2, mem_req_gib=10),
        _s("annotatepeaks", "per_sample", 45, 15, cpus=2),
        _s("consensus_peaks", "merge_all", 70, 4, cpus=3),
        _s("multiqc", "merge_all", 60, 1.5, cpus=2),
    ), n_samples=8, sample_gb_median=3.0, sample_gb_sigma=0.5),

    "atacseq": WorkflowTemplate("atacseq", (
        _s("fastqc", "per_sample", 35, 12, cpus=2),
        _s("trimgalore", "per_sample", 55, 28, cpus=4),
        _s("bowtie2", "per_sample", 120, 85, cpus=6, mem_req_gib=16,
           mem_base_gib=6, mem_per_gb_gib=0.3),
        _s("merge_library", "per_sample", 40, 20, cpus=3),
        _s("macs2", "per_sample", 75, 30, cpus=2, mem_req_gib=10),
        _s("ataqv", "per_sample", 35, 12, cpus=2),
        _s("consensus", "merge_all", 65, 3, cpus=3),
        _s("multiqc", "merge_all", 60, 1.5, cpus=2),
    ), n_samples=8, sample_gb_median=3.5, sample_gb_sigma=0.5),

    "methylseq": WorkflowTemplate("methylseq", (
        _s("fastqc", "per_sample", 35, 12, cpus=2),
        _s("trimgalore", "per_sample", 60, 30, cpus=4),
        _s("bismark_align", "per_sample", 200, 130, cpus=8, mem_req_gib=24,
           mem_base_gib=12, mem_per_gb_gib=0.5),
        _s("deduplicate", "per_sample", 50, 30, cpus=3),
        _s("methylation_extract", "per_sample", 90, 50, cpus=4, mem_req_gib=12),
        _s("bismark_report", "per_sample", 25, 8, cpus=1),
        _s("multiqc", "merge_all", 60, 1.5, cpus=2),
    ), n_samples=6, sample_gb_median=5.0, sample_gb_sigma=0.55),

    "viralrecon": WorkflowTemplate("viralrecon", (
        _s("fastqc", "per_sample", 25, 10, cpus=2),
        _s("fastp", "per_sample", 40, 20, cpus=4),
        _s("bowtie2", "per_sample", 70, 50, cpus=6, mem_req_gib=12),
        _s("ivar_trim", "per_sample", 30, 15, cpus=2),
        _s("ivar_variants", "per_sample", 45, 20, cpus=2),
        _s("ivar_consensus", "per_sample", 40, 18, cpus=2),
        _s("pangolin", "per_sample", 35, 8, cpus=2),
        _s("multiqc", "merge_all", 55, 1.5, cpus=2),
    ), n_samples=12, sample_gb_median=1.5, sample_gb_sigma=0.45),

    "mag": WorkflowTemplate("mag", (
        _s("fastqc", "per_sample", 35, 12, cpus=2),
        _s("fastp", "per_sample", 55, 28, cpus=4),
        _s("megahit_assembly", "per_sample", 350, 220, cpus=8, mem_req_gib=28,
           mem_base_gib=16, mem_per_gb_gib=1.2, jitter_sigma=0.35),
        _s("bowtie2_backmap", "per_sample", 90, 60, cpus=6, mem_req_gib=12),
        _s("metabat2_binning", "per_sample", 120, 70, cpus=4, mem_req_gib=16),
        _s("checkm", "per_sample", 150, 60, cpus=4, mem_req_gib=20),
        _s("gtdbtk", "merge_all", 200, 10, cpus=8, mem_req_gib=28),
        _s("multiqc", "merge_all", 60, 1.5, cpus=2),
    ), n_samples=5, sample_gb_median=6.0, sample_gb_sigma=0.6),

    "ampliseq": WorkflowTemplate("ampliseq", (
        _s("fastqc", "per_sample", 25, 10, cpus=2),
        _s("cutadapt", "per_sample", 35, 18, cpus=3),
        _s("dada2_filter", "per_sample", 60, 30, cpus=4, mem_req_gib=10),
        _s("dada2_denoise", "merge_all", 220, 12, cpus=8, mem_req_gib=20,
           jitter_sigma=0.3),
        _s("taxonomy", "merge_all", 140, 6, cpus=4, mem_req_gib=16),
        _s("barplots", "merge_all", 40, 2, cpus=2),
        _s("multiqc", "merge_all", 50, 1.5, cpus=2),
    ), n_samples=14, sample_gb_median=0.8, sample_gb_sigma=0.4),

    "eager": WorkflowTemplate("eager", (
        _s("fastqc", "per_sample", 30, 12, cpus=2),
        _s("adapterremoval", "per_sample", 55, 28, cpus=4),
        _s("bwa_aln", "per_sample", 140, 95, cpus=6, mem_req_gib=16,
           mem_base_gib=8, mem_per_gb_gib=0.3),
        _s("dedup", "per_sample", 45, 25, cpus=3),
        _s("damageprofiler", "per_sample", 50, 20, cpus=2),
        _s("qualimap", "per_sample", 40, 18, cpus=2),
        _s("genotyping", "per_sample", 85, 40, cpus=4, mem_req_gib=12),
        _s("multiqc", "merge_all", 60, 1.5, cpus=2),
    ), n_samples=7, sample_gb_median=3.0, sample_gb_sigma=0.65),
}

NF_CORE_WORKFLOWS: Tuple[str, ...] = tuple(NF_CORE_TEMPLATES)


def build_workflow(template: str | WorkflowTemplate, seed: int = 0,
                   workflow_id: Optional[str] = None,
                   n_samples: Optional[int] = None) -> WorkflowDAG:
    """Instantiate a template into a concrete DAG with seeded ground truth."""
    tpl = NF_CORE_TEMPLATES[template] if isinstance(template, str) else template
    rng = np.random.default_rng(seed)
    wid = workflow_id or f"{tpl.name}-s{seed}"
    dag = WorkflowDAG(wid, tpl.name)
    ns = n_samples or tpl.n_samples

    sample_gb = tpl.sample_gb_median * rng.lognormal(
        0.0, tpl.sample_gb_sigma, size=ns)

    def mk(stage: Stage, idx: str, input_gb: float,
           deps: Sequence[str]) -> Tuple[str, float]:
        jit = float(rng.lognormal(0.0, stage.jitter_sigma))
        runtime = (stage.runtime_base_s + stage.runtime_per_gb_s * input_gb) * jit
        true_peak = int((stage.mem_base_gib
                         + stage.mem_per_gb_gib * input_gb) * jit * GiB)
        req = int(stage.mem_req_gib * GiB)
        out_gb = input_gb * stage.out_ratio
        tid = f"{wid}.{stage.name}.{idx}"
        spec = TaskSpec(
            task_id=tid,
            name=stage.name,
            inputs=(DataRef(f"in:{tid}", int(input_gb * GiB)),),
            outputs=(DataRef(f"out:{tid}", int(out_gb * GiB)),),
            resources=Resources(cpus=stage.cpus, mem_bytes=req),
            params={"sim": {"peak_mem": min(true_peak, req),
                            "cpu_utilisation": 0.75}},
            base_runtime_s=runtime,
        )
        dag.add_task(spec, deps=deps)
        return tid, out_gb

    # walk stages, tracking each sample's frontier (task ids + data size)
    frontier: List[Tuple[List[str], float]] = [([], sample_gb[i]) for i in range(ns)]
    all_prev: List[str] = []
    for stage in tpl.stages:
        new_all: List[str] = []
        if stage.kind == "per_sample":
            for i in range(ns):
                deps, gb = frontier[i]
                tid, out_gb = mk(stage, f"s{i}", gb, deps)
                frontier[i] = ([tid], out_gb)
                new_all.append(tid)
        elif stage.kind == "scatter":
            for i in range(ns):
                deps, gb = frontier[i]
                tids = []
                for p in range(stage.scatter):
                    tid, _ = mk(stage, f"s{i}p{p}", gb / stage.scatter, deps)
                    tids.append(tid)
                frontier[i] = (tids, gb * stage.out_ratio)
                new_all.extend(tids)
        elif stage.kind == "gather":
            for i in range(ns):
                deps, gb = frontier[i]
                tid, out_gb = mk(stage, f"s{i}", gb, deps)
                frontier[i] = ([tid], out_gb)
                new_all.append(tid)
        elif stage.kind == "merge_all":
            deps = [t for f, _ in frontier for t in f] or all_prev
            total_gb = sum(gb for _, gb in frontier)
            tid, out_gb = mk(stage, "all", total_gb, deps)
            frontier = [([tid], out_gb / ns) for _ in range(ns)]
            new_all.append(tid)
        else:
            raise ValueError(f"unknown stage kind {stage.kind!r}")
        all_prev = new_all

    dag.validate()
    return dag


# ---------------------------------------------------------------------------
# Trace replay: streamed workflow arrivals (the "heavy traffic" regime).
#
# The paper's companion proposal argues the CWSI must hold up under
# *streams* of arriving workflows, not curated bursts. An arrival
# schedule is a plain list of descriptors (cheap: no DAGs yet); the
# replayer materialises each workflow's DAG lazily AT its arrival
# instant and submits it through the engine's command seam, so resident
# memory tracks live work — a million-task replay never holds a million
# task objects at once.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Arrival:
    """One workflow arrival in a replayable trace (no DAG until it fires)."""

    time: float
    workflow_id: str
    template: str
    seed: int
    n_samples: Optional[int] = None
    share: Optional[float] = None       # tenant weight, declared pre-submit


def poisson_arrivals(
    n_workflows: int,
    rate: float,
    templates: Sequence[str] = NF_CORE_WORKFLOWS,
    seed: int = 0,
    n_samples: Optional[int] = None,
    share_classes: Sequence[float] = (),
) -> List[Arrival]:
    """Poisson arrival process: i.i.d. exponential gaps at ``rate``/s.

    Every workflow is its own tenant (fresh workflow id); templates cycle
    through a seeded shuffle of ``templates`` and each arrival draws its
    own ground-truth seed, so the whole trace is a pure function of
    ``seed``. ``share_classes``, when given, assigns tenant weights
    round-robin (e.g. ``(1.0, 2.0, 4.0)`` for three service classes).
    """
    if n_workflows <= 0:
        raise ValueError(f"n_workflows must be positive, got {n_workflows!r}")
    if not rate > 0:
        raise ValueError(f"rate must be positive, got {rate!r}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_workflows)
    times = np.cumsum(gaps)
    picks = rng.integers(0, len(templates), size=n_workflows)
    seeds = rng.integers(0, 2**31 - 1, size=n_workflows)
    out: List[Arrival] = []
    for i in range(n_workflows):
        tpl = templates[int(picks[i])]
        out.append(Arrival(
            time=float(times[i]),
            workflow_id=f"{tpl}-r{seed}-{i:06d}",
            template=tpl,
            seed=int(seeds[i]),
            n_samples=n_samples,
            share=(share_classes[i % len(share_classes)]
                   if share_classes else None),
        ))
    return out


def burst_arrivals(
    n_bursts: int,
    burst_size: int,
    period: float,
    templates: Sequence[str] = NF_CORE_WORKFLOWS,
    seed: int = 0,
    n_samples: Optional[int] = None,
    share_classes: Sequence[float] = (),
) -> List[Arrival]:
    """Periodic same-instant bursts (cron-shaped load): ``burst_size``
    workflows land together every ``period`` seconds — the worst case for
    same-timestamp coalescing and the best case for micro-batching."""
    if n_bursts <= 0 or burst_size <= 0:
        raise ValueError("n_bursts and burst_size must be positive")
    if not period > 0:
        raise ValueError(f"period must be positive, got {period!r}")
    rng = np.random.default_rng(seed)
    n = n_bursts * burst_size
    picks = rng.integers(0, len(templates), size=n)
    seeds = rng.integers(0, 2**31 - 1, size=n)
    out: List[Arrival] = []
    for i in range(n):
        tpl = templates[int(picks[i])]
        out.append(Arrival(
            time=float((i // burst_size) * period),
            workflow_id=f"{tpl}-b{seed}-{i:06d}",
            template=tpl,
            seed=int(seeds[i]),
            n_samples=n_samples,
            share=(share_classes[i % len(share_classes)]
                   if share_classes else None),
        ))
    return out


def recorded_arrivals(records: Iterable[Mapping[str, Any]]) -> List[Arrival]:
    """Build a trace from recorded rows (e.g. a parsed JSON/CSV log):
    each row needs ``time``/``workflow_id``/``template``/``seed`` and may
    carry ``n_samples``/``share``. Rows are sorted by arrival time."""
    out = [Arrival(
        time=float(r["time"]),
        workflow_id=str(r["workflow_id"]),
        template=str(r["template"]),
        seed=int(r["seed"]),
        n_samples=(None if r.get("n_samples") is None
                   else int(r["n_samples"])),
        share=(None if r.get("share") is None else float(r["share"])),
    ) for r in records]
    out.sort(key=lambda a: a.time)
    return out


def template_task_count(template: str, n_samples: Optional[int] = None) -> int:
    """Tasks one instantiation will submit (closed-form, no DAG built)."""
    tpl = NF_CORE_TEMPLATES[template]
    ns = n_samples or tpl.n_samples
    total = 0
    for stage in tpl.stages:
        if stage.kind == "merge_all":
            total += 1
        elif stage.kind == "scatter":
            total += ns * stage.scatter
        else:
            total += ns
    return total


def trace_task_count(arrivals: Sequence[Arrival]) -> int:
    return sum(template_task_count(a.template, a.n_samples) for a in arrivals)


class TraceReplayer:
    """Streams an arrival schedule into a running simulation.

    One ``call_at`` hook is in flight at a time: each arrival builds its
    DAG (the expensive part) at its own virtual instant, declares the
    tenant's share if the trace carries one, submits the workflow through
    the engine's command seam, and chains the next arrival — so the
    replayer holds O(1) pending state no matter how long the trace is,
    and the event queue never sees the whole future schedule at once.

    ``on_arrival(now, replayer)`` (if given) fires after every submission
    — the probe benches use to sample resident-state gauges mid-replay.
    """

    def __init__(
        self,
        sim: Any,                      # ClusterSimulator (duck-typed)
        arrivals: Iterable[Arrival],
        build: Callable[..., WorkflowDAG] = build_workflow,
        on_arrival: Optional[Callable[[float, "TraceReplayer"], None]] = None,
    ) -> None:
        self._sim = sim
        self._arrivals: Iterator[Arrival] = iter(arrivals)
        self._build = build
        self._on_arrival = on_arrival
        self.submitted_workflows = 0
        self.submitted_tasks = 0
        self.last_arrival_time = 0.0

    def start(self) -> "TraceReplayer":
        """Arm the first arrival (before ``sim.run()``)."""
        self._chain_next()
        return self

    def _chain_next(self) -> None:
        nxt = next(self._arrivals, None)
        if nxt is None:
            return
        self._sim.call_at(nxt.time, lambda now, a=nxt: self._fire(a, now))

    def _fire(self, arrival: Arrival, now: float) -> None:
        cws = self._sim.cws
        dag = self._build(arrival.template, seed=arrival.seed,
                          workflow_id=arrival.workflow_id,
                          n_samples=arrival.n_samples)
        if arrival.share is not None:
            cws.apply(_cmd.SetShare(arrival.workflow_id, arrival.share), now)
        cws.apply(_cmd.SubmitWorkflow(dag), now)
        self.submitted_workflows += 1
        self.submitted_tasks += len(dag)
        self.last_arrival_time = now
        # chain AFTER submitting: the next arrival's event lands behind
        # this instant's remaining events, keeping (time, seq) order
        self._chain_next()
        if self._on_arrival is not None:
            self._on_arrival(now, self)


def workflow_summary(dag: WorkflowDAG) -> Dict[str, float]:
    ranks = dag.ranks()
    work = sum(t.spec.base_runtime_s for t in dag.tasks.values())
    cp = sum(dag.tasks[t].spec.base_runtime_s for t in dag.critical_path(
        {tid: dag.tasks[tid].spec.base_runtime_s for tid in dag.tasks}))
    return {
        "tasks": len(dag),
        "depth": max(ranks.values()),
        "total_work_s": round(work, 1),
        "critical_path_s": round(cp, 1),
        "parallelism": round(work / max(cp, 1e-9), 2),
    }
