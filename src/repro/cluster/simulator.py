"""Discrete-event cluster simulator — the resource-manager side of the CWS.

Reproduces the paper's evaluation methodology without a physical cluster:
the CWS engine makes *exactly the same calls* it would against Kubernetes;
the simulator supplies node events, executes launches by sampling task
runtimes, and reports completions. Ground truth per task comes from the
trace generator (``base_runtime_s``, true peak memory in
``spec.params['sim']``), while the scheduler only sees requests + history —
so prediction plugins are evaluated honestly.

Faults modelled (all seeded & deterministic):
  * node crashes (running tasks requeued by the CWS) and elastic re-joins,
  * node-level slowdowns (contention → straggler mitigation kicks in),
  * per-task straggler noise (heavy-tailed runtime multiplier),
  * OOM kills when the granted allocation < true peak memory.
"""
from __future__ import annotations

import heapq
import itertools
import math
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core import commands as _cmd
from ..core.dag import Task, TaskState, WorkflowDAG
from ..core.scheduler import CommonWorkflowScheduler, NodeInfo, TaskResult


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: Dict[str, Any] = field(compare=False, default_factory=dict)


@dataclass
class SimConfig:
    seed: int = 0
    runtime_noise_sigma: float = 0.08      # lognormal sigma on every task
    straggler_prob: float = 0.0            # per-task heavy-tail probability
    straggler_factor: Tuple[float, float] = (2.0, 5.0)
    staging_bandwidth: float = 1e9         # bytes/s for non-local inputs
    staging_latency: float = 0.5           # container/pod start overhead (s)
    oom_check: bool = True
    speculation_period: float = 15.0       # how often to scan for stragglers


class ClusterSimulator:
    """Implements the ``ClusterAdapter`` protocol against virtual time."""

    def __init__(self, nodes: List[NodeInfo], config: Optional[SimConfig] = None):
        self.config = config or SimConfig()
        self.rng = np.random.default_rng(self.config.seed)
        self.now = 0.0
        self._heap: List[_Event] = []
        self._seq = itertools.count()
        self._initial_nodes = list(nodes)
        self.cws: Optional[CommonWorkflowScheduler] = None
        # launch bookkeeping: task_id -> live launch generation
        self._launch_gen: Dict[str, int] = {}
        self._gen = itertools.count(1)
        self._node_of_launch: Dict[int, str] = {}
        self._task_of_launch: Dict[int, Task] = {}
        # node -> unretired launch generations; NODE_FAIL consults only
        # this (not every launch in history)
        self._gens_on_node: Dict[str, set] = {}
        self.launches = 0
        self.kills = 0

    # ------------------------------------------------------------------
    def attach(self, cws: CommonWorkflowScheduler) -> None:
        self.cws = cws
        cws.staging_bandwidth = self.config.staging_bandwidth
        # every resource-manager event enters the engine as a command
        # through the apply seam, so an attached journal records exactly
        # this simulator's history (replay-identical by construction)
        for n in self._initial_nodes:
            cws.apply(_cmd.AddNode(n), self.now)
        if cws.enable_speculation:
            self._push(self.now + self.config.speculation_period, "SPEC_CHECK", {})

    # ---- ClusterAdapter protocol ----
    def launch(self, task: Task, node: str, mem_alloc: int) -> None:
        assert self.cws is not None
        gen = next(self._gen)
        self._launch_gen[task.task_id] = gen
        self._node_of_launch[gen] = node
        self._task_of_launch[gen] = task
        self._gens_on_node.setdefault(node, set()).add(gen)
        # engine-issued launch id, reported back with start/finish so the
        # engine itself can reject reports from superseded launches
        lid = task.launch_id
        self.launches += 1

        sim = task.spec.params.get("sim", {})
        true_peak = int(sim.get("peak_mem", 0))
        # ground-truth runtime: direct submissions carry base_runtime_s;
        # tasks that crossed the CWSI wire carry it in params["sim"]
        # (the wire format intentionally omits ground truth fields)
        base_runtime = task.spec.base_runtime_s or float(sim.get("runtime", 0.0))
        # staging: move non-resident inputs, plus constant startup latency
        remote = sum(r.size_bytes for r in task.spec.inputs
                     if r.location is not None and r.location != node)
        stage = self.config.staging_latency + remote / self.config.staging_bandwidth
        start = self.now + stage

        speed = self.cws.nodes[node].info.speed_factor if node in self.cws.nodes else 1.0
        noise = float(self.rng.lognormal(0.0, self.config.runtime_noise_sigma))
        straggle = 1.0
        if self.config.straggler_prob > 0 and self.rng.random() < self.config.straggler_prob:
            lo, hi = self.config.straggler_factor
            straggle = float(self.rng.uniform(lo, hi))
        runtime = base_runtime / max(speed, 1e-6) * noise * straggle

        if self.config.oom_check and true_peak > 0 and mem_alloc < true_peak:
            # OOM-kill partway through (the task dies when it touches the
            # allocation boundary — model at the matching fraction of runtime)
            frac = max(0.05, min(1.0, mem_alloc / true_peak))
            self._push(start, "TASK_START", {"gen": gen, "lid": lid})
            self._push(start + runtime * frac, "TASK_FINISH", {
                "gen": gen, "lid": lid,
                "result": TaskResult(False, peak_mem_bytes=mem_alloc, oom=True,
                                     reason="OOMKilled"),
            })
            return

        cpu_eff = float(sim.get("cpu_utilisation", 0.8))
        self._push(start, "TASK_START", {"gen": gen, "lid": lid})
        self._push(start + runtime, "TASK_FINISH", {
            "gen": gen, "lid": lid,
            "result": TaskResult(
                True,
                peak_mem_bytes=true_peak or mem_alloc // 2,
                cpu_seconds=runtime * task.spec.resources.cpus * cpu_eff,
            ),
        })

    def kill(self, task_id: str) -> None:
        gen = self._launch_gen.pop(task_id, None)   # invalidate in-flight events
        if gen is not None:
            self._retire(gen)
        self.kills += 1

    def _retire(self, gen: int) -> None:
        """Drop a launch's bookkeeping once it can never go live again."""
        node = self._node_of_launch.pop(gen, None)
        self._task_of_launch.pop(gen, None)
        if node is not None:
            gens = self._gens_on_node.get(node)
            if gens is not None:
                gens.discard(gen)
                if not gens:
                    del self._gens_on_node[node]

    # ------------------------------------------------------------------
    # fault & elasticity injection (schedule before run())
    # ------------------------------------------------------------------
    def fail_node_at(self, time: float, node: str) -> None:
        self._push(time, "NODE_FAIL", {"node": node})

    def join_node_at(self, time: float, info: NodeInfo) -> None:
        self._push(time, "NODE_JOIN", {"info": info})

    def slow_node_at(self, time: float, node: str, speed_factor: float) -> None:
        self._push(time, "NODE_SLOW", {"node": node, "speed": speed_factor})

    def submit_workflow_at(self, time: float, dag: WorkflowDAG) -> None:
        self._push(time, "WF_SUBMIT", {"dag": dag})

    def call_at(self, time: float, fn: Callable[[float], None]) -> None:
        """Run ``fn(now)`` at a virtual instant (before that instant's
        coalesced scheduling round). The hook for mid-run tenant-policy
        changes — e.g. a CWSI ``PUT .../share`` flip driving preemptive
        arbitration — without teaching the event loop new verbs."""
        self._push(time, "CALL", {"fn": fn})

    # ------------------------------------------------------------------
    def _push(self, time: float, kind: str, payload: Dict[str, Any]) -> None:
        heapq.heappush(self._heap, _Event(time, next(self._seq), kind, payload))

    def _live(self, gen: int) -> Optional[Task]:
        task = self._task_of_launch.get(gen)
        if task is None:
            return None
        if self._launch_gen.get(task.task_id) != gen:
            return None   # superseded (retried/killed) launch
        return task

    def run(self, until: float = math.inf, max_events: int = 10_000_000) -> float:
        """Drain the event loop; returns the final virtual time.

        Scheduling rounds are coalesced: event handlers only mark the
        engine pending (``request_schedule``), and one round runs per
        *virtual timestamp* once every same-time event has been applied —
        a W-wide same-timestamp completion burst costs one round, not W.
        With ``sync_schedule=True`` engines the handlers schedule inline
        and ``schedule_pending`` is a no-op, restoring the old cadence.
        """
        assert self.cws is not None, "attach() a scheduler first"
        cws = self.cws
        # work deferred before run() (e.g. CWSI batch submits) starts now
        cws.schedule_pending(self.now)
        n = 0
        while self._heap and self._heap[0].time <= until:
            n += 1
            if n > max_events:
                raise RuntimeError("simulator event budget exceeded (livelock?)")
            ev = heapq.heappop(self._heap)
            self.now = ev.time

            if ev.kind == "TASK_START":
                task = self._live(ev.payload["gen"])
                if task is not None:
                    cws.apply(_cmd.TaskStarted(
                        task.task_id, launch_id=ev.payload.get("lid")),
                        self.now)

            elif ev.kind == "TASK_FINISH":
                gen = ev.payload["gen"]
                task = self._live(gen)
                if task is not None:
                    self._launch_gen.pop(task.task_id, None)
                    cws.apply(_cmd.TaskFinished(
                        task.task_id, ev.payload["result"],
                        launch_id=ev.payload.get("lid")), self.now)
                self._retire(gen)

            elif ev.kind == "NODE_FAIL":
                node = ev.payload["node"]
                # drop in-flight events of launches on that node (only the
                # node's unretired generations — not every launch ever made)
                for gen in list(self._gens_on_node.get(node, ())):
                    task = self._task_of_launch.get(gen)
                    if task is not None \
                            and self._launch_gen.get(task.task_id) == gen:
                        self._launch_gen.pop(task.task_id, None)
                    self._retire(gen)
                cws.apply(_cmd.RemoveNode(node), self.now)

            elif ev.kind == "NODE_JOIN":
                cws.apply(_cmd.AddNode(ev.payload["info"]), self.now)

            elif ev.kind == "NODE_SLOW":
                cws.apply(_cmd.SetNodeSpeed(ev.payload["node"],
                                            ev.payload["speed"]), self.now)

            elif ev.kind == "WF_SUBMIT":
                cws.apply(_cmd.SubmitWorkflow(ev.payload["dag"]), self.now)

            elif ev.kind == "CALL":
                ev.payload["fn"](self.now)

            elif ev.kind == "SPEC_CHECK":
                # only a round that can change anything: a speculative
                # launch consumed resources (capacity/ready changes from
                # other events already request their own rounds — an
                # unconditional request here ran one empty round per
                # wakeup for the whole run)
                if cws.check_speculation(self.now):
                    cws.request_schedule(self.now)
                # finished workflows retire out of cws.dags, so this
                # re-arm scan is over live work only, not history
                if any(not d.finished() for d in cws.dags.values()):
                    self._push(self.now + self.config.speculation_period,
                               "SPEC_CHECK", {})

            # same-timestamp batch drained (launches may re-arm the current
            # timestamp; the loop then drains and flushes it again) → run
            # the single coalesced round for this instant
            if not self._heap or self._heap[0].time > self.now:
                cws.schedule_pending(self.now)
        # a round requested by the final batch (or by an `until` cutoff)
        # still runs at the last processed instant
        cws.schedule_pending(self.now)
        return self.now


def run_workflow(
    dag: WorkflowDAG,
    nodes: List[NodeInfo],
    strategy: str = "rank_min_rr",
    sim_config: Optional[SimConfig] = None,
    **cws_kwargs: Any,
) -> Tuple[float, CommonWorkflowScheduler]:
    """Convenience: simulate one workflow to completion, return (makespan, cws)."""
    makespans, cws = run_workflows([dag], nodes, strategy, sim_config,
                                   **cws_kwargs)
    return makespans[dag.workflow_id], cws


def run_workflows(
    dags: List[WorkflowDAG],
    nodes: List[NodeInfo],
    strategy: str = "rank_min_rr",
    sim_config: Optional[SimConfig] = None,
    submit_times: Optional[List[float]] = None,
    shares: Optional[Dict[str, float]] = None,
    arbiter: str = "first_appearance",
    **cws_kwargs: Any,
) -> Tuple[Dict[str, float], CommonWorkflowScheduler]:
    """Multi-tenant convenience: run concurrent workflows under an arbiter.

    ``shares`` maps workflow_id → fair-share weight / strict priority
    (set before any submission, as a tenant would over the CWSI); returns
    per-workflow makespans keyed by workflow_id plus the scheduler.
    """
    if shares and arbiter == "first_appearance":
        # shares are harmless tenant policy (the CWSI accepts them any
        # time), but under this arbiter they do nothing — surface the
        # no-op instead of raising so arbiter-comparison sweeps can reuse
        # one tenant config
        warnings.warn(
            "shares have no effect under the first_appearance arbiter; "
            "pass arbiter='fair_share' or 'strict_priority' to use them",
            stacklevel=2)
    sim = ClusterSimulator(nodes, sim_config)
    cws = CommonWorkflowScheduler(adapter=sim, strategy=strategy,
                                  arbiter=arbiter, **cws_kwargs)
    for wid, share in (shares or {}).items():
        cws.set_workflow_share(wid, share)
    sim.attach(cws)
    times = submit_times if submit_times is not None else [0.0] * len(dags)
    if len(times) != len(dags):
        raise ValueError(
            f"submit_times has {len(times)} entries for {len(dags)} workflows")
    for dag, t in zip(dags, times):
        sim.submit_workflow_at(t, dag)
    sim.run()
    unfinished = [d for d in dags if not d.finished()]
    if unfinished:
        raise RuntimeError("workflows did not finish: " + ", ".join(
            f"{d.workflow_id} "
            f"({sum(t.state.terminal for t in d.tasks.values())}/{len(d)})"
            for d in unfinished))
    return (
        {d.workflow_id: cws.provenance.makespan(d.workflow_id) for d in dags},
        cws,
    )
