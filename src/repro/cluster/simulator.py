"""Discrete-event cluster simulator — the resource-manager side of the CWS.

Reproduces the paper's evaluation methodology without a physical cluster:
the CWS engine makes *exactly the same calls* it would against Kubernetes;
the simulator supplies node events, executes launches by sampling task
runtimes, and reports completions. Ground truth per task comes from the
trace generator (``base_runtime_s``, true peak memory in
``spec.params['sim']``), while the scheduler only sees requests + history —
so prediction plugins are evaluated honestly.

Faults modelled (all seeded & deterministic):
  * node crashes (running tasks requeued by the CWS) and elastic re-joins,
  * node-level slowdowns (contention → straggler mitigation kicks in),
  * per-task straggler noise (heavy-tailed runtime multiplier),
  * OOM kills when the granted allocation < true peak memory,
  * declarative chaos plans (``faults.FaultPlan``): correlated
    failure-domain outages, node flap, injected transient/permanent task
    failures, and silently lost start/finish reports — the launch-level
    faults arrive through ``fault_injector`` (set by
    ``FaultInjector.arm``) from the plan's own seeded generator, so the
    simulator's random stream is untouched and a run without a plan is
    bit-identical to before the hook existed.
"""
from __future__ import annotations

import heapq
import itertools
import math
import warnings
from bisect import insort
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core import commands as _cmd
from ..core.dag import Task, TaskState, WorkflowDAG
from ..core.scheduler import CommonWorkflowScheduler, NodeInfo, TaskResult

# Events are plain tuples ``(time, seq, kind, payload)``: the seq is
# globally unique, so tuple comparison decides on (time, seq) and never
# reaches the unorderable payload — and C-speed tuple compares are what
# both queue implementations sort by, keeping the (time, seq) total
# order identical between them.
_Event = Tuple[float, int, str, Dict[str, Any]]


class _EventHeap:
    """Baseline binary-heap event queue (the pre-wheel implementation,
    kept for the wheel's bit-identity oracle and benchmarking)."""

    __slots__ = ("_heap",)

    def __init__(self) -> None:
        self._heap: List[_Event] = []

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, ev: _Event) -> None:
        heapq.heappush(self._heap, ev)

    def pop(self) -> _Event:
        return heapq.heappop(self._heap)

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None


class _TimeWheel:
    """Calendar-queue event queue (Brown '88): amortized O(1) push/pop.

    Events hash into width-``w`` time slots, slot → bucket modulo a
    power-of-two bucket count; each bucket is kept sorted. A cursor walks
    slots in increasing order, popping a bucket's head while the head
    belongs to the cursor's slot, so a pop costs O(1) plus the rotation
    to the next occupied slot. The bucket count tracks the resident
    population (grow at 2x occupancy, shrink below 1/2x, width
    re-estimated as queued-span / population) so rotations stay short;
    a fruitless full rotation (population clustered far ahead of the
    cursor) falls back to a direct min scan that teleports the cursor.

    Bit-identity with the heap: pops follow the event tuples' own
    (time, seq) order. Slot membership uses the SAME ``int(t / w)`` on
    the push and pop sides, so float rounding can never disagree about
    an event's slot; the cursor is always <= the global minimum's slot
    (pops restore it, pushes clamp it), and slot number is monotone in
    time, so the increasing-slot walk always surfaces the minimum first.
    The one-event head lookahead keeps ``peek_time`` O(1) for the
    driver's after-every-event batch-boundary check.
    """

    __slots__ = ("_buckets", "_mask", "_width", "_cursor", "_size", "_head")

    _MIN_BUCKETS = 8
    _MAX_BUCKETS = 1 << 20

    def __init__(self) -> None:
        self._buckets: List[List[_Event]] = [
            [] for _ in range(self._MIN_BUCKETS)]
        self._mask = self._MIN_BUCKETS - 1
        self._width = 1.0
        self._cursor = 0              # slot number (NOT bucket index)
        self._size = 0                # events resident in buckets
        self._head: Optional[_Event] = None   # global minimum, out-of-bucket

    def __len__(self) -> int:
        return self._size + (self._head is not None)

    def peek_time(self) -> Optional[float]:
        return self._head[0] if self._head is not None else None

    def push(self, ev: _Event) -> None:
        head = self._head
        if head is None:
            self._head = ev
            return
        if ev < head:                 # new global min: swap into the head
            self._head = ev
            ev = head
        slot = int(ev[0] / self._width)
        if slot < self._cursor:
            self._cursor = slot
        insort(self._buckets[slot & self._mask], ev)
        self._size += 1
        if self._size > 2 * (self._mask + 1) \
                and self._mask + 1 < self._MAX_BUCKETS:
            self._resize()

    def pop(self) -> _Event:
        ev = self._head
        if ev is None:
            raise IndexError("pop from an empty time wheel")
        self._head = self._take_min() if self._size else None
        return ev

    def _take_min(self) -> _Event:
        width = self._width
        mask = self._mask
        buckets = self._buckets
        slot = self._cursor
        for _ in range(mask + 1):
            b = buckets[slot & mask]
            if b and int(b[0][0] / width) <= slot:
                self._cursor = slot
                ev = b.pop(0)
                break
            slot += 1
        else:
            # fruitless full rotation: the minimum lives more than one
            # wheel revolution ahead — take it directly (each bucket's
            # head is its min) and teleport the cursor to its slot
            best: Optional[_Event] = None
            best_b: Optional[List[_Event]] = None
            for b in buckets:
                if b and (best is None or b[0] < best):
                    best = b[0]
                    best_b = b
            assert best_b is not None
            ev = best_b.pop(0)
            self._cursor = int(ev[0] / width)
        self._size -= 1
        n = mask + 1
        if n > self._MIN_BUCKETS and self._size < n // 2:
            self._resize()
        return ev

    def _resize(self) -> None:
        events: List[_Event] = []
        for b in self._buckets:
            events.extend(b)
        n = self._MIN_BUCKETS
        while n < len(events):
            n <<= 1
        n = min(n, self._MAX_BUCKETS)
        if events:
            tmin = min(ev[0] for ev in events)
            tmax = max(ev[0] for ev in events)
            span = tmax - tmin
            if span > 0.0:
                # width ~ mean gap: one resident event per slot on
                # average, so rotations advance ~1 slot per pop
                self._width = span / len(events)
            self._cursor = int(tmin / self._width)
        self._buckets = [[] for _ in range(n)]
        self._mask = n - 1
        width = self._width
        mask = self._mask
        for ev in events:
            insort(self._buckets[int(ev[0] / width) & mask], ev)


_EVENT_QUEUES = {"wheel": _TimeWheel, "heap": _EventHeap}

# externally injected (finite-by-construction) event kinds: their
# firing is progress for the stall-based livelock guard in ``run``
_PROGRESS_KINDS = frozenset(
    {"WF_SUBMIT", "CALL", "NODE_FAIL", "NODE_JOIN", "NODE_SLOW"})


@dataclass
class SimConfig:
    seed: int = 0
    runtime_noise_sigma: float = 0.08      # lognormal sigma on every task
    straggler_prob: float = 0.0            # per-task heavy-tail probability
    straggler_factor: Tuple[float, float] = (2.0, 5.0)
    staging_bandwidth: float = 1e9         # bytes/s for non-local inputs
    staging_latency: float = 0.5           # container/pod start overhead (s)
    oom_check: bool = True
    speculation_period: float = 15.0       # how often to scan for stragglers
    event_queue: str = "wheel"             # "wheel" | "heap" (bit-identical)


class ClusterSimulator:
    """Implements the ``ClusterAdapter`` protocol against virtual time."""

    def __init__(self, nodes: List[NodeInfo], config: Optional[SimConfig] = None):
        self.config = config or SimConfig()
        self.rng = np.random.default_rng(self.config.seed)
        self.now = 0.0
        try:
            self._queue = _EVENT_QUEUES[self.config.event_queue]()
        except KeyError:
            raise ValueError(
                f"unknown event_queue {self.config.event_queue!r} "
                f"(choose from {sorted(_EVENT_QUEUES)})") from None
        self._seq = itertools.count()
        # deferred-round bookkeeping (engine decision_lag > 0): the one
        # outstanding ROUND wakeup's instant, plus counters the tests and
        # bench read — lag 0 must never defer (the tripwire)
        self._round_wakeup: Optional[float] = None
        self.round_deferrals = 0
        self.round_wakeups = 0
        self.events_processed = 0     # lifetime, across run() calls
        self._initial_nodes = list(nodes)
        self.cws: Optional[CommonWorkflowScheduler] = None
        # launch bookkeeping: task_id -> live launch generation
        self._launch_gen: Dict[str, int] = {}
        self._gen = itertools.count(1)
        self._node_of_launch: Dict[int, str] = {}
        self._task_of_launch: Dict[int, Task] = {}
        # node -> unretired launch generations; NODE_FAIL consults only
        # this (not every launch in history)
        self._gens_on_node: Dict[str, set] = {}
        # gang launches only: gen -> every member node, so _retire can
        # deregister the generation from all of them (singles stay on
        # the _node_of_launch fast path)
        self._members_of_launch: Dict[int, Tuple[str, ...]] = {}
        self.launches = 0
        self.kills = 0
        # per-launch fault oracle (faults.FaultInjector.arm installs it);
        # None means every launch runs and reports cleanly
        self.fault_injector: Optional[Any] = None

    # ------------------------------------------------------------------
    def attach(self, cws: CommonWorkflowScheduler) -> None:
        self.cws = cws
        cws.staging_bandwidth = self.config.staging_bandwidth
        # every resource-manager event enters the engine as a command
        # through the apply seam, so an attached journal records exactly
        # this simulator's history (replay-identical by construction)
        for n in self._initial_nodes:
            cws.apply(_cmd.AddNode(n), self.now)
        if cws.enable_speculation:
            self._push(self.now + self.config.speculation_period, "SPEC_CHECK", {})
        if cws.report_lease is not None:
            self._push(self.now + cws.report_lease, "LEASE_CHECK", {})

    # ---- ClusterAdapter protocol ----
    def launch(self, task: Task, node: str, mem_alloc: int) -> None:
        assert self.cws is not None
        gen = next(self._gen)
        self._launch_gen[task.task_id] = gen
        self._node_of_launch[gen] = node
        self._task_of_launch[gen] = task
        self._gens_on_node.setdefault(node, set()).add(gen)
        members = task.gang_nodes if len(task.gang_nodes) > 1 else (node,)
        if len(members) > 1:
            # gang: the generation is live on every member, so losing ANY
            # member node kills the whole launch (all-or-nothing execution
            # mirrors all-or-nothing placement)
            self._members_of_launch[gen] = tuple(members)
            for m in members:
                if m != node:
                    self._gens_on_node.setdefault(m, set()).add(gen)
        # engine-issued launch id, reported back with start/finish so the
        # engine itself can reject reports from superseded launches
        lid = task.launch_id
        self.launches += 1

        sim = task.spec.params.get("sim", {})
        true_peak = int(sim.get("peak_mem", 0))
        # ground-truth runtime: direct submissions carry base_runtime_s;
        # tasks that crossed the CWSI wire carry it in params["sim"]
        # (the wire format intentionally omits ground truth fields)
        base_runtime = task.spec.base_runtime_s or float(sim.get("runtime", 0.0))
        # staging: move non-resident inputs, plus constant startup latency
        remote = sum(r.size_bytes for r in task.spec.inputs
                     if r.location is not None and r.location != node)
        stage = self.config.staging_latency + remote / self.config.staging_bandwidth
        start = self.now + stage

        if task.committed_s > 0.0:
            # resume from the last committed checkpoint: only the
            # remaining base-runtime work is executed on this launch
            base_runtime = max(base_runtime - task.committed_s, 0.0)

        speed = self.cws.nodes[node].info.speed_factor if node in self.cws.nodes else 1.0
        if len(members) > 1:
            # a gang paces at its slowest member (synchronous steps)
            speed = min(
                (self.cws.nodes[m].info.speed_factor
                 for m in members if m in self.cws.nodes),
                default=speed)
        noise = float(self.rng.lognormal(0.0, self.config.runtime_noise_sigma))
        straggle = 1.0
        if self.config.straggler_prob > 0 and self.rng.random() < self.config.straggler_prob:
            lo, hi = self.config.straggler_factor
            straggle = float(self.rng.uniform(lo, hi))
        runtime = base_runtime / max(speed, 1e-6) * noise * straggle
        req_nodes = task.spec.resources.nodes
        if req_nodes > 1 and len(members) < req_nodes:
            # elastic resize: fewer data-parallel replicas → proportionally
            # more wall-clock per step
            runtime *= req_nodes / len(members)

        if self.config.oom_check and true_peak > 0 and mem_alloc < true_peak:
            # OOM-kill partway through (the task dies when it touches the
            # allocation boundary — model at the matching fraction of runtime)
            frac = max(0.05, min(1.0, mem_alloc / true_peak))
            self._push(start, "TASK_START", {"gen": gen, "lid": lid})
            self._push(start + runtime * frac, "TASK_FINISH", {
                "gen": gen, "lid": lid,
                "result": TaskResult(False, peak_mem_bytes=mem_alloc, oom=True,
                                     reason="OOMKilled"),
            })
            return

        if self.fault_injector is not None:
            v = self.fault_injector.launch_faults(task)
            if v.fail:
                # injected failure, reported like any real one: the task
                # dies partway through and the engine spends a retry
                self._push(start, "TASK_START", {"gen": gen, "lid": lid})
                self._push(start + runtime * v.fail_frac, "TASK_FINISH", {
                    "gen": gen, "lid": lid,
                    "result": TaskResult(False, peak_mem_bytes=mem_alloc // 2,
                                         reason=v.reason),
                })
                return
            if v.drop_start:
                # silent loss at launch: neither report ever arrives, the
                # generation stays live until a report lease reclaims it
                return
            if v.drop_finish:
                # death mid-run: the start lands, then silence
                self._push(start, "TASK_START", {"gen": gen, "lid": lid})
                return

        cpu_eff = float(sim.get("cpu_utilisation", 0.8))
        self._push(start, "TASK_START", {"gen": gen, "lid": lid})
        self._push(start + runtime, "TASK_FINISH", {
            "gen": gen, "lid": lid,
            "result": TaskResult(
                True,
                peak_mem_bytes=true_peak or mem_alloc // 2,
                cpu_seconds=runtime * task.spec.resources.cpus * cpu_eff,
            ),
        })

    def kill(self, task_id: str) -> None:
        gen = self._launch_gen.pop(task_id, None)   # invalidate in-flight events
        if gen is not None:
            self._retire(gen)
        self.kills += 1

    def _retire(self, gen: int) -> None:
        """Drop a launch's bookkeeping once it can never go live again."""
        node = self._node_of_launch.pop(gen, None)
        self._task_of_launch.pop(gen, None)
        members = self._members_of_launch.pop(gen, None)
        for m in (members if members is not None else
                  ((node,) if node is not None else ())):
            gens = self._gens_on_node.get(m)
            if gens is not None:
                gens.discard(gen)
                if not gens:
                    del self._gens_on_node[m]

    # ------------------------------------------------------------------
    # fault & elasticity injection (schedule before run())
    # ------------------------------------------------------------------
    def fail_node_at(self, time: float, node: str) -> None:
        self._push(time, "NODE_FAIL", {"node": node})

    def join_node_at(self, time: float, info: NodeInfo) -> None:
        self._push(time, "NODE_JOIN", {"info": info})

    def slow_node_at(self, time: float, node: str, speed_factor: float) -> None:
        self._push(time, "NODE_SLOW", {"node": node, "speed": speed_factor})

    def submit_workflow_at(self, time: float, dag: WorkflowDAG) -> None:
        self._push(time, "WF_SUBMIT", {"dag": dag})

    def call_at(self, time: float, fn: Callable[[float], None]) -> None:
        """Run ``fn(now)`` at a virtual instant (before that instant's
        coalesced scheduling round). The hook for mid-run tenant-policy
        changes — e.g. a CWSI ``PUT .../share`` flip driving preemptive
        arbitration — without teaching the event loop new verbs."""
        self._push(time, "CALL", {"fn": fn})

    # ------------------------------------------------------------------
    def _push(self, time: float, kind: str, payload: Dict[str, Any]) -> None:
        self._queue.push((time, next(self._seq), kind, payload))

    def _live(self, gen: int) -> Optional[Task]:
        task = self._task_of_launch.get(gen)
        if task is None:
            return None
        if self._launch_gen.get(task.task_id) != gen:
            return None   # superseded (retried/killed) launch
        return task

    def run(self, until: float = math.inf,
            max_events: Optional[int] = None,
            stall_events: int = 1_000_000) -> float:
        """Drain the event loop; returns the final virtual time.

        Scheduling rounds are coalesced: event handlers only mark the
        engine pending (``request_schedule``), and one round runs per
        *virtual timestamp* once every same-time event has been applied —
        a W-wide same-timestamp completion burst costs one round, not W.
        An engine with ``decision_lag > 0`` stretches the window across
        timestamps: the pending round is deferred until its deadline
        (first request + lag), absorbing every event in between; a ROUND
        wakeup guarantees the deadline is reached even when the queue
        holds nothing before it. With ``sync_schedule=True`` engines the
        handlers schedule inline and ``schedule_pending`` is a no-op,
        restoring the old cadence.

        Liveness is guarded by *stall* accounting, not an absolute event
        budget (the old hard ``max_events=10_000_000`` counted benign
        SPEC_CHECK wakeups and task events alike, aborting legitimate
        million-task replays): progress is a task settling for good
        (``cws.tasks_settled`` — SUCCEEDED or terminal ERROR) or an
        externally injected, finite-by-construction event (submission,
        node churn, ``call_at`` hook); the run aborts once
        ``stall_events`` events pass without either. A clean replay
        settles a task every few events regardless of workload size,
        while a genuine requeue livelock — launch/kill churn with
        nothing ever settling — still trips the guard. Pass
        ``max_events`` for the old absolute cap on top.
        """
        assert self.cws is not None, "attach() a scheduler first"
        cws = self.cws
        # work deferred before run() (e.g. CWSI batch submits) starts now
        cws.schedule_pending(self.now)
        queue = self._queue
        n = 0
        stall = 0
        settled = cws.tasks_settled
        while queue and queue.peek_time() <= until:
            n += 1
            if max_events is not None and n > max_events:
                raise RuntimeError("simulator event budget exceeded (livelock?)")
            _, _, kind, payload = ev = queue.pop()
            self.now = ev[0]

            if kind == "TASK_START":
                task = self._live(payload["gen"])
                if task is not None:
                    cws.apply(_cmd.TaskStarted(
                        task.task_id, launch_id=payload.get("lid")),
                        self.now)

            elif kind == "TASK_FINISH":
                gen = payload["gen"]
                task = self._live(gen)
                if task is not None:
                    self._launch_gen.pop(task.task_id, None)
                    cws.apply(_cmd.TaskFinished(
                        task.task_id, payload["result"],
                        launch_id=payload.get("lid")), self.now)
                self._retire(gen)

            elif kind == "NODE_FAIL":
                node = payload["node"]
                # drop in-flight events of launches on that node (only the
                # node's unretired generations — not every launch ever made)
                for gen in list(self._gens_on_node.get(node, ())):
                    task = self._task_of_launch.get(gen)
                    if task is not None \
                            and self._launch_gen.get(task.task_id) == gen:
                        self._launch_gen.pop(task.task_id, None)
                    self._retire(gen)
                cws.apply(_cmd.RemoveNode(node), self.now)

            elif kind == "NODE_JOIN":
                cws.apply(_cmd.AddNode(payload["info"]), self.now)

            elif kind == "NODE_SLOW":
                cws.apply(_cmd.SetNodeSpeed(payload["node"],
                                            payload["speed"]), self.now)

            elif kind == "WF_SUBMIT":
                cws.apply(_cmd.SubmitWorkflow(payload["dag"]), self.now)

            elif kind == "CALL":
                payload["fn"](self.now)

            elif kind == "ROUND":
                # bare wakeup for a deferred round: the flush below sees
                # the deadline reached. A stale wakeup (its round already
                # ran earlier, pulled in by an intervening event batch)
                # drains as a harmless no-op.
                pass

            elif kind == "SPEC_CHECK":
                # only a round that can change anything: a speculative
                # launch consumed resources (capacity/ready changes from
                # other events already request their own rounds — an
                # unconditional request here ran one empty round per
                # wakeup for the whole run)
                if cws.check_speculation(self.now):
                    cws.request_schedule(self.now)
                # O(1) re-arm: the engine maintains its unfinished-
                # workflow set at the state transitions — the old
                # ``any(not d.finished() for d in cws.dags.values())``
                # scan here cost O(live workflows) per periodic wakeup
                if cws.has_unfinished_work():
                    self._push(self.now + self.config.speculation_period,
                               "SPEC_CHECK", {})

            elif kind == "LEASE_CHECK":
                # the engine journals a LeaseCheck command only when a
                # lease or quarantine is actually due, so the periodic
                # wakeup is journal-silent on clean runs
                cws.lease_check(self.now)
                if cws.has_unfinished_work() or len(queue) > 0:
                    self._push(self.now + cws.report_lease,
                               "LEASE_CHECK", {})

            if cws.tasks_settled != settled or kind in _PROGRESS_KINDS:
                settled = cws.tasks_settled
                stall = 0
            else:
                stall += 1
                if stall > stall_events:
                    raise RuntimeError(
                        f"simulator stalled: {stall} events without a "
                        f"task settling or external input (livelock?)")

            # same-timestamp batch drained (launches may re-arm the current
            # timestamp; the loop then drains and flushes it again) → run
            # the single coalesced round for this instant, or defer it to
            # its micro-batching deadline
            nt = queue.peek_time()
            if (nt is None or nt > self.now) and cws._sched_pending:
                deadline = cws._sched_deadline
                if deadline <= self.now:      # decision_lag 0 always lands here
                    cws.schedule_pending(self.now)
                    self._round_wakeup = None
                else:
                    self.round_deferrals += 1
                    if (nt is None or nt > deadline) \
                            and self._round_wakeup != deadline:
                        self._round_wakeup = deadline
                        self.round_wakeups += 1
                        self._push(deadline, "ROUND", {})
        # a round requested by the final batch (or by an `until` cutoff)
        # still runs at the last processed instant
        cws.schedule_pending(self.now)
        self.events_processed += n
        return self.now


def run_workflow(
    dag: WorkflowDAG,
    nodes: List[NodeInfo],
    strategy: str = "rank_min_rr",
    sim_config: Optional[SimConfig] = None,
    **cws_kwargs: Any,
) -> Tuple[float, CommonWorkflowScheduler]:
    """Convenience: simulate one workflow to completion, return (makespan, cws)."""
    makespans, cws = run_workflows([dag], nodes, strategy, sim_config,
                                   **cws_kwargs)
    return makespans[dag.workflow_id], cws


def run_workflows(
    dags: List[WorkflowDAG],
    nodes: List[NodeInfo],
    strategy: str = "rank_min_rr",
    sim_config: Optional[SimConfig] = None,
    submit_times: Optional[List[float]] = None,
    shares: Optional[Dict[str, float]] = None,
    arbiter: str = "first_appearance",
    **cws_kwargs: Any,
) -> Tuple[Dict[str, float], CommonWorkflowScheduler]:
    """Multi-tenant convenience: run concurrent workflows under an arbiter.

    ``shares`` maps workflow_id → fair-share weight / strict priority
    (set before any submission, as a tenant would over the CWSI); returns
    per-workflow makespans keyed by workflow_id plus the scheduler.
    """
    if shares and arbiter == "first_appearance":
        # shares are harmless tenant policy (the CWSI accepts them any
        # time), but under this arbiter they do nothing — surface the
        # no-op instead of raising so arbiter-comparison sweeps can reuse
        # one tenant config
        warnings.warn(
            "shares have no effect under the first_appearance arbiter; "
            "pass arbiter='fair_share' or 'strict_priority' to use them",
            stacklevel=2)
    sim = ClusterSimulator(nodes, sim_config)
    cws = CommonWorkflowScheduler(adapter=sim, strategy=strategy,
                                  arbiter=arbiter, **cws_kwargs)
    for wid, share in (shares or {}).items():
        cws.set_workflow_share(wid, share)
    sim.attach(cws)
    times = submit_times if submit_times is not None else [0.0] * len(dags)
    if len(times) != len(dags):
        raise ValueError(
            f"submit_times has {len(times)} entries for {len(dags)} workflows")
    for dag, t in zip(dags, times):
        sim.submit_workflow_at(t, dag)
    sim.run()
    unfinished = [d for d in dags if not d.finished()]
    if unfinished:
        raise RuntimeError("workflows did not finish: " + ", ".join(
            f"{d.workflow_id} "
            f"({sum(t.state.terminal for t in d.tasks.values())}/{len(d)})"
            for d in unfinished))
    return (
        {d.workflow_id: cws.provenance.makespan(d.workflow_id) for d in dags},
        cws,
    )
