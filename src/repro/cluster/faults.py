"""Declarative, seeded fault injection for the cluster substrate.

A ``FaultPlan`` is a frozen description of everything that will go wrong
in a run: correlated failure-domain outages (every node sharing a label
dies at one instant, and optionally rejoins), single-node flap
(down-then-up), per-launch report faults (transient failures, permanent
"doomed" tasks, silently lost start/finish reports), and — via
``FaultyTransport`` — lossy/duplicating CWSI message delivery. Plans are
data: the same plan against the same cluster and seed replays the exact
same fault sequence, so chaos runs are as reproducible as clean ones.

The injection points are the seams the system already has:

* node-level faults become ordinary ``NODE_FAIL``/``NODE_JOIN`` events
  in the simulator's queue (``FaultInjector.arm``);
* per-launch faults are consulted by ``ClusterSimulator.launch`` through
  ``sim.fault_injector`` (a lost report means the event is simply never
  pushed — exactly what a dead executor looks like to the scheduler,
  and what the engine's report leases exist to reclaim);
* transport faults wrap any ``str -> str`` CWSI transport, raising
  ``TransportError`` for losses (the retrying client's cue) and
  re-delivering for duplicates (the dedup window's problem).

The injector draws from its own ``numpy`` generator, never the
simulator's, and every probabilistic draw is guarded by ``prob > 0`` —
a zero plan consumes no randomness, so a run with an all-zero FaultPlan
attached is bit-identical to a run with no injector at all.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from ..core.cwsi_client import TransportError
from ..core.scheduler import NodeInfo


@dataclass(frozen=True)
class DomainOutage:
    """All nodes labelled ``{key: domain}`` fail at ``time``; with a
    ``duration`` they rejoin together at ``time + duration``."""

    time: float
    domain: str
    duration: Optional[float] = None
    key: str = "rack"


@dataclass(frozen=True)
class NodeFlap:
    """One node drops at ``time`` and rejoins ``down_for`` later."""

    time: float
    node: str
    down_for: float


@dataclass(frozen=True)
class FaultPlan:
    """The full seeded fault schedule for one run (see module docstring).

    ``doomed_tasks`` fail on *every* launch (permanent failures: the
    retry budget drains and the task goes terminal-ERROR);
    ``transient_failure_prob`` fails any given launch once in a while
    (a retry normally succeeds). ``drop_start_prob`` loses both of a
    launch's reports (silent executor death at launch),
    ``drop_finish_prob`` loses only the finish (death mid-run) — both
    are invisible to the scheduler until a report lease expires."""

    seed: int = 0
    outages: Tuple[DomainOutage, ...] = ()
    flaps: Tuple[NodeFlap, ...] = ()
    transient_failure_prob: float = 0.0
    doomed_tasks: Tuple[str, ...] = ()
    drop_start_prob: float = 0.0
    drop_finish_prob: float = 0.0

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)


@dataclass(frozen=True)
class LaunchVerdict:
    """What the injector decided for one launch."""

    fail: bool = False
    reason: Optional[str] = None
    fail_frac: float = 0.5        # fraction of the runtime before death
    drop_start: bool = False      # lose start AND finish reports
    drop_finish: bool = False     # lose only the finish report


_CLEAN = LaunchVerdict()


class FaultInjector:
    """Executes a ``FaultPlan`` against one simulator run."""

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self._doomed = frozenset(plan.doomed_tasks)
        self.injected_failures = 0
        self.dropped_starts = 0
        self.dropped_finishes = 0
        self.outage_nodes = 0

    def arm(self, sim: Any, nodes: List[NodeInfo]) -> None:
        """Schedule the plan's node faults into ``sim``'s event queue and
        hook per-launch faults (sets ``sim.fault_injector``).

        Call after constructing the simulator with ``nodes`` and before
        ``run()``; unknown domains/nodes raise immediately — a plan that
        silently injects nothing is worse than one that fails loudly."""
        by_name = {n.name: n for n in nodes}
        for o in self.plan.outages:
            members = [n for n in nodes
                       if n.labels.get(o.key) == o.domain]
            if not members:
                raise ValueError(
                    f"no nodes carry {o.key}={o.domain!r}: outage would "
                    f"inject nothing")
            for n in members:
                sim.fail_node_at(o.time, n.name)
                self.outage_nodes += 1
                if o.duration is not None:
                    sim.join_node_at(o.time + o.duration, n)
        for f in self.plan.flaps:
            info = by_name.get(f.node)
            if info is None:
                raise ValueError(f"unknown flap node {f.node!r}")
            sim.fail_node_at(f.time, f.node)
            sim.join_node_at(f.time + f.down_for, info)
        sim.fault_injector = self

    def launch_faults(self, task: Any) -> LaunchVerdict:
        """Draw this launch's fate. At most one fault per launch, checked
        in severity order; every draw is guarded so zero-prob plans pull
        nothing from the generator."""
        p = self.plan
        if task.task_id in self._doomed:
            self.injected_failures += 1
            return LaunchVerdict(fail=True, reason="injected: permanent")
        if p.transient_failure_prob > 0 \
                and self.rng.random() < p.transient_failure_prob:
            self.injected_failures += 1
            return LaunchVerdict(fail=True, reason="injected: transient")
        if p.drop_start_prob > 0 \
                and self.rng.random() < p.drop_start_prob:
            self.dropped_starts += 1
            return LaunchVerdict(drop_start=True)
        if p.drop_finish_prob > 0 \
                and self.rng.random() < p.drop_finish_prob:
            self.dropped_finishes += 1
            return LaunchVerdict(drop_finish=True)
        return _CLEAN


class FaultyTransport:
    """Wrap a ``str -> str`` CWSI transport with seeded message faults.

    * ``drop_request_prob`` — the request never arrives: ``TransportError``
      without touching the inner transport.
    * ``drop_response_prob`` — the server acted but the answer is lost:
      inner transport called, then ``TransportError``. The ambiguous
      case exactly-once dedup exists for.
    * ``duplicate_prob`` — the request is delivered twice; the extra
      delivery's response is discarded. With ``delay_prob`` the second
      copy is held back and lands *after* later traffic (reordering).

    Raised ``TransportError``\\ s are what ``ReliableCWSIClient`` retries
    on; a bare ``CWSIClient`` over a faulty transport simply fails."""

    def __init__(self, inner: Callable[[str], str],
                 drop_request_prob: float = 0.0,
                 drop_response_prob: float = 0.0,
                 duplicate_prob: float = 0.0,
                 delay_prob: float = 0.0,
                 seed: int = 0) -> None:
        self.inner = inner
        self.drop_request_prob = float(drop_request_prob)
        self.drop_response_prob = float(drop_response_prob)
        self.duplicate_prob = float(duplicate_prob)
        self.delay_prob = float(delay_prob)
        self.rng = np.random.default_rng(seed)
        self._delayed: List[str] = []
        self.dropped_requests = 0
        self.dropped_responses = 0
        self.duplicated_requests = 0
        self.delayed_deliveries = 0

    def __call__(self, raw: str) -> str:
        if self._delayed:
            # late duplicates from earlier calls land first, out of
            # order with respect to their original traffic
            for old in self._delayed:
                self.inner(old)
            self.delayed_deliveries += len(self._delayed)
            self._delayed.clear()
        if self.drop_request_prob > 0 \
                and self.rng.random() < self.drop_request_prob:
            self.dropped_requests += 1
            raise TransportError("request lost in transit")
        resp = self.inner(raw)
        if self.duplicate_prob > 0 \
                and self.rng.random() < self.duplicate_prob:
            self.duplicated_requests += 1
            if self.delay_prob > 0 \
                    and self.rng.random() < self.delay_prob:
                self._delayed.append(raw)
            else:
                self.inner(raw)
        if self.drop_response_prob > 0 \
                and self.rng.random() < self.drop_response_prob:
            self.dropped_responses += 1
            raise TransportError("response lost in transit")
        return resp

    def flush(self) -> None:
        """Deliver any still-held delayed duplicates."""
        for old in self._delayed:
            self.inner(old)
        self.delayed_deliveries += len(self._delayed)
        self._delayed.clear()
